//! The invariant rules. Every rule is a token-sequence analysis over the
//! [`crate::analysis::tokenizer`] stream — no parse tree, just patterns
//! plus balanced-delimiter spans and, for the cross-file rules, the
//! shared [`crate::analysis::callgraph::CallGraph`]. See the module docs
//! in [`crate::analysis`] for what each rule enforces and why, and for
//! the known approximations (name-keyed call resolution, lexical guard
//! scopes, comparator-closure detection).

use std::collections::{BTreeSet, HashMap, HashSet};

use super::callgraph::{
    cfg_test_start, enclosing_fn, file_stem, fn_spans, in_region, match_brace, match_paren, norm,
    tarjan_sccs, Call, CallGraph, FileTokens, FnNode,
};
use super::tokenizer::{Token, TokenKind};
use super::{Finding, SourceFile};

fn mk(rule: &'static str, file: &SourceFile, line: u32, message: String) -> Finding {
    Finding { rule, path: file.path.clone(), line, message }
}

// ---------------------------------------------------------------------------
// clock_discipline

/// Files whose *job* is reading the wall clock: the real half of
/// `testkit::Clock`, the phase-timer instruments, the CLI front end, and
/// the bench/harness wall-timing sites.
fn wall_clock_allowed(path: &str) -> bool {
    let p = norm(path);
    p.ends_with("testkit/clock.rs")
        || p.ends_with("util/timer.rs")
        || p.ends_with("main.rs")
        || p.contains("benches/")
        || p.contains("harness/")
}

/// No `Instant::now` / `SystemTime::now` outside the wall-clock files,
/// and no `thread::sleep` anywhere but benches: coordinator and select
/// code must take time from the service [`crate::testkit::Clock`] so the
/// control plane stays deterministic under the virtual clock.
pub(crate) fn clock_discipline(ft: &FileTokens) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &ft.code;
    let allowed = wall_clock_allowed(&ft.file.path);
    let benches = norm(&ft.file.path).contains("benches/");
    for (i, t) in code.iter().enumerate() {
        if t.kind != TokenKind::Ident {
            continue;
        }
        let calls = |a: &str, b: &str| {
            t.is_ident(a)
                && code.get(i + 1).is_some_and(|x| x.is_punct(':'))
                && code.get(i + 2).is_some_and(|x| x.is_punct(':'))
                && code.get(i + 3).is_some_and(|x| x.is_ident(b))
        };
        if !allowed && (calls("Instant", "now") || calls("SystemTime", "now")) {
            out.push(mk(
                "clock_discipline",
                ft.file,
                t.line,
                format!(
                    "{}::now() bypasses testkit::Clock; read the service clock instead",
                    t.text
                ),
            ));
        } else if !benches && calls("thread", "sleep") {
            out.push(mk(
                "clock_discipline",
                ft.file,
                t.line,
                "thread::sleep waits in wall time; park on the virtual clock instead".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// poison_discipline

/// Every `.lock()` on a poisonable mutex must recover the guard with
/// `unwrap_or_else(|e| e.into_inner())` — the repo-wide idiom — rather
/// than `.unwrap()`/`.expect()` (panic amplification: one poisoned lock
/// cascades through every thread that touches it) or `?` (propagates a
/// non-actionable error). A bare `.lock()` whose result is not consumed
/// inline is fine: that is `util::sync::OrderedMutex` or a helper whose
/// body is checked where it lives.
pub(crate) fn poison_discipline(ft: &FileTokens) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &ft.code;
    for i in 0..code.len() {
        let is_lock_call = code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')'));
        if !is_lock_call {
            continue;
        }
        let line = code[i + 1].line;
        let after = &code[i + 4..];
        if after.first().is_some_and(|t| t.is_punct('?')) {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                ".lock()? propagates poison; recover with unwrap_or_else(|e| e.into_inner())"
                    .to_string(),
            ));
            continue;
        }
        if !after.first().is_some_and(|t| t.is_punct('.')) {
            continue;
        }
        let Some(m) = after.get(1) else { continue };
        if m.is_ident("unwrap") || m.is_ident("expect") {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                format!(
                    ".lock().{}() panics on poison; recover with unwrap_or_else(|e| e.into_inner())",
                    m.text
                ),
            ));
        } else if m.is_ident("unwrap_or_else")
            && !after.iter().take(16).any(|t| t.is_ident("into_inner"))
        {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                ".lock().unwrap_or_else(..) must recover the guard via e.into_inner()".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// panic_boundary

fn backend_trait_methods(files: &[FileTokens]) -> HashSet<String> {
    let mut methods = HashSet::new();
    for ft in files {
        let code = &ft.code;
        for i in 0..code.len() {
            if code[i].is_ident("trait")
                && code.get(i + 1).is_some_and(|t| t.is_ident("DatasetBackend"))
            {
                let Some(open) = (i + 2..code.len()).find(|&j| code[j].is_punct('{')) else {
                    continue;
                };
                let end = match_brace(code, open);
                for k in open..end {
                    if code[k].is_ident("fn") {
                        if let Some(name) = code.get(k + 1) {
                            methods.insert(name.text.clone());
                        }
                    }
                }
            }
        }
    }
    methods
}

/// In the worker execution paths (`coordinator/dispatch.rs` for the
/// in-process loop, `cluster/worker.rs` for the wire serve loop; test
/// modules excluded), every `backend.<DatasetBackend method>(…)` call must
/// be lexically inside a `catch_unwind(…)` span — or inside a function
/// whose every call site in the file is (`solve_group`/`run_query`/
/// `handle_shard_op`, which are only ever entered through the
/// fault-isolation boundary). The method set is read from the
/// `DatasetBackend` trait declaration itself, and the receiver-name
/// convention (`backend`) is shared by both files.
pub(crate) fn panic_boundary(files: &[FileTokens]) -> Vec<Finding> {
    let methods = backend_trait_methods(files);
    if methods.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for ft in files {
        let path = norm(&ft.file.path);
        if !path.ends_with("coordinator/dispatch.rs") && !path.ends_with("cluster/worker.rs") {
            continue;
        }
        let limit = cfg_test_start(&ft.code);
        let code = &ft.code[..limit];
        let regions: Vec<(usize, usize)> = (0..code.len())
            .filter(|&i| {
                code[i].is_ident("catch_unwind") && code.get(i + 1).is_some_and(|t| t.is_punct('('))
            })
            .map(|i| (i, match_paren(code, i + 1)))
            .collect();
        let spans = fn_spans(code);
        let mut protected: HashSet<&str> = HashSet::new();
        for s in &spans {
            let mut sites = 0usize;
            let mut covered = true;
            for i in 0..code.len() {
                let own_body = s.body.is_some_and(|(b0, b1)| i >= b0 && i <= b1);
                if code[i].is_ident(&s.name)
                    && code.get(i + 1).is_some_and(|t| t.is_punct('('))
                    && (i == 0 || !code[i - 1].is_ident("fn"))
                    && !own_body
                {
                    sites += 1;
                    covered &= in_region(&regions, i);
                }
            }
            if sites > 0 && covered {
                protected.insert(s.name.as_str());
            }
        }
        for i in 0..code.len() {
            let method = match code.get(i + 2) {
                Some(t) if t.kind == TokenKind::Ident => &t.text,
                _ => continue,
            };
            let is_backend_call = code[i].is_ident("backend")
                && code.get(i + 1).is_some_and(|t| t.is_punct('.'))
                && methods.contains(method);
            if !is_backend_call || in_region(&regions, i) {
                continue;
            }
            if enclosing_fn(&spans, i).is_some_and(|s| protected.contains(s.name.as_str())) {
                continue;
            }
            out.push(mk(
                "panic_boundary",
                ft.file,
                code[i + 2].line,
                format!(
                    "DatasetBackend::{method} runs outside catch_unwind; \
                     a backend panic here kills the worker"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// metrics_triple_entry

struct Field {
    name: String,
    ty: String,
    public: bool,
    line: u32,
}

fn struct_fields(code: &[Token], name: &str) -> Option<Vec<Field>> {
    for i in 0..code.len() {
        if !(code[i].is_ident("struct") && code.get(i + 1).is_some_and(|t| t.is_ident(name))) {
            continue;
        }
        let mut j = i + 2;
        while j < code.len() && !code[j].is_punct('{') {
            if code[j].is_punct(';') {
                return Some(Vec::new());
            }
            j += 1;
        }
        let end = match_brace(code, j);
        let mut fields = Vec::new();
        for k in j + 1..end {
            let is_field = code[k].kind == TokenKind::Ident
                && code.get(k + 1).is_some_and(|t| t.is_punct(':'))
                && !code.get(k + 2).is_some_and(|t| t.is_punct(':'))
                && !code[k - 1].is_punct(':');
            if is_field {
                fields.push(Field {
                    name: code[k].text.clone(),
                    ty: code.get(k + 2).map(|t| t.text.clone()).unwrap_or_default(),
                    public: code[k - 1].is_ident("pub"),
                    line: code[k].line,
                });
            }
        }
        return Some(fields);
    }
    None
}

fn display_impl_span(code: &[Token], for_name: &str) -> Option<(usize, usize)> {
    for i in 0..code.len() {
        if code[i].is_ident("Display")
            && code.get(i + 1).is_some_and(|t| t.is_ident("for"))
            && code.get(i + 2).is_some_and(|t| t.is_ident(for_name))
        {
            let open = (i + 3..code.len()).find(|&j| code[j].is_punct('{'))?;
            return Some((open, match_brace(code, open)));
        }
    }
    None
}

fn span_has_field_init(code: &[Token], span: (usize, usize), name: &str) -> bool {
    (span.0..=span.1).any(|k| {
        code[k].is_ident(name)
            && code.get(k + 1).is_some_and(|t| t.is_punct(':'))
            && !code.get(k + 2).is_some_and(|t| t.is_punct(':'))
    })
}

fn span_has_self_field(code: &[Token], span: (usize, usize), name: &str) -> bool {
    (span.0..=span.1).any(|k| {
        code[k].is_ident("self")
            && code.get(k + 1).is_some_and(|t| t.is_punct('.'))
            && code.get(k + 2).is_some_and(|t| t.is_ident(name))
    })
}

/// Every `pub … : AtomicU64` counter declared on `Metrics`
/// (`coordinator/metrics.rs`) must appear three more times, all
/// maintained by hand today: as a `Snapshot` field, copied in
/// `Metrics::snapshot()`, and rendered in `Display for Snapshot`. A
/// counter that misses any leg silently vanishes from observability.
pub(crate) fn metrics_triple_entry(files: &[FileTokens]) -> Vec<Finding> {
    let mut out = Vec::new();
    for ft in files {
        if !norm(&ft.file.path).ends_with("coordinator/metrics.rs") {
            continue;
        }
        let code = &ft.code;
        let Some(metrics_fields) = struct_fields(code, "Metrics") else { continue };
        let counters: Vec<&Field> =
            metrics_fields.iter().filter(|f| f.public && f.ty == "AtomicU64").collect();
        let snap_fields = struct_fields(code, "Snapshot");
        let snap_body =
            fn_spans(code).into_iter().find(|s| s.name == "snapshot").and_then(|s| s.body);
        let display = display_impl_span(code, "Snapshot");
        let (Some(snap_fields), Some(snap_body), Some(display)) = (snap_fields, snap_body, display)
        else {
            out.push(mk(
                "metrics_triple_entry",
                ft.file,
                1,
                "expected struct Snapshot, fn snapshot() and a Display impl alongside Metrics"
                    .to_string(),
            ));
            continue;
        };
        for c in counters {
            if !snap_fields.iter().any(|f| f.name == c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` has no matching Snapshot field", c.name),
                ));
            }
            if !span_has_field_init(code, snap_body, &c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` is not copied in Metrics::snapshot()", c.name),
                ));
            }
            if !span_has_self_field(code, display, &c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` has no Display arm on Snapshot", c.name),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// lock_order

#[derive(Clone)]
struct Held {
    node: usize,
    depth: usize,
    var: Option<String>,
    temp: bool,
}

/// Cross-file lock-order graph over the named lock fields (`name:
/// Mutex<…>` / `name: OrderedMutex<…>` declarations; nodes are
/// `<file stem>.<field>`). Within every function body, a resolved
/// `receiver.lock()` acquisition draws an edge from each lock still
/// lexically held (let-bound guards live to their block or `drop(var)`;
/// temporaries to the end of the statement) to the acquired one; calls to
/// named local functions are expanded through the call graph's name-keyed
/// direct-lock-set fixpoint so helper-routed acquisitions still
/// contribute edges. Any cycle in the resulting graph is a finding: two
/// code paths that disagree about acquisition order are a deadlock
/// waiting for a schedule.
pub(crate) fn lock_order(files: &[FileTokens], cg: &CallGraph) -> Vec<Finding> {
    // Pass 0: discover lock-field nodes.
    let mut nodes: Vec<String> = Vec::new();
    let mut per_file: Vec<HashMap<String, usize>> = Vec::new();
    let mut global: HashMap<String, Vec<usize>> = HashMap::new();
    for ft in files {
        let stem = file_stem(&ft.file.path);
        let code = &ft.code;
        let mut map = HashMap::new();
        for i in 0..code.len() {
            let is_decl = code[i].kind == TokenKind::Ident
                && code.get(i + 1).is_some_and(|t| t.is_punct(':'))
                && code
                    .get(i + 2)
                    .is_some_and(|t| t.is_ident("Mutex") || t.is_ident("OrderedMutex"))
                && code.get(i + 3).is_some_and(|t| t.is_punct('<'))
                && (i == 0 || !code[i - 1].is_punct(':'));
            if !is_decl {
                continue;
            }
            let field = code[i].text.clone();
            let name = format!("{stem}.{field}");
            let node = match nodes.iter().position(|n| *n == name) {
                Some(p) => p,
                None => {
                    nodes.push(name);
                    nodes.len() - 1
                }
            };
            map.insert(field.clone(), node);
            global.entry(field).or_default().push(node);
        }
        per_file.push(map);
    }
    if nodes.is_empty() {
        return Vec::new();
    }

    // Resolve `receiver.lock()` at the `.` token `i`; empty = unresolved.
    let resolve = |fidx: usize, code: &[Token], i: usize| -> Vec<usize> {
        if i == 0 {
            return Vec::new();
        }
        let recv = &code[i - 1];
        if recv.kind != TokenKind::Ident {
            return Vec::new();
        }
        if let Some(&n) = per_file[fidx].get(&recv.text) {
            return vec![n];
        }
        global.get(&recv.text).cloned().unwrap_or_default()
    };

    let is_lock_call = |code: &[Token], i: usize| {
        code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')'))
    };

    // Pass A: per-function direct lock sets, propagated through calls by
    // the shared call-graph fixpoint (a helper that locks makes its
    // callers lock too). A `.lock()` site that resolved to a known field
    // is dropped from call expansion: its lock is already in the direct
    // set, and following the bare name `lock` from there would smear
    // util::sync's internal mutex over every caller.
    let locks_by_name = cg.fixpoint_union(
        |f: &FnNode| {
            let code = &files[f.file].code;
            let mut direct = BTreeSet::new();
            for i in f.body.0..=f.body.1 {
                if is_lock_call(code, i) {
                    direct.extend(resolve(f.file, code, i));
                }
            }
            direct
        },
        |f: &FnNode, call: &Call| {
            let code = &files[f.file].code;
            !(call.name == "lock"
                && call.at > 0
                && is_lock_call(code, call.at - 1)
                && !resolve(f.file, code, call.at - 1).is_empty())
        },
    );

    // Pass B: held-scope walk per function, drawing held → acquired edges.
    let mut edges: HashMap<(usize, usize), (String, u32)> = HashMap::new();
    for f in &cg.fns {
        let code = &files[f.file].code;
        let mut held: Vec<Held> = Vec::new();
        let mut depth = 0usize;
        let mut edge = |held: &[Held], to: usize, line: u32, edges: &mut HashMap<_, _>| {
            for h in held {
                if h.node != to {
                    edges
                        .entry((h.node, to))
                        .or_insert_with(|| (files[f.file].file.path.clone(), line));
                }
            }
        };
        let mut i = f.body.0;
        while i <= f.body.1 {
            let t = &code[i];
            if t.is_punct('{') {
                depth += 1;
            } else if t.is_punct('}') {
                depth = depth.saturating_sub(1);
                held.retain(|h| h.depth <= depth);
            } else if t.is_punct(';') {
                held.retain(|h| !h.temp);
            } else if is_lock_call(code, i) {
                let targets = resolve(f.file, code, i);
                if targets.is_empty() {
                    // unresolved receiver (`self.lock()` helpers): treat
                    // as a call named `lock`, expanded below via i+1
                } else {
                    for &n in &targets {
                        edge(&held, n, code[i + 1].line, &mut edges);
                    }
                    let (let_bound, var) = statement_binding(code, f.body.0, i);
                    for &n in &targets {
                        held.push(Held { node: n, depth, var: var.clone(), temp: !let_bound });
                    }
                    i += 4;
                    continue;
                }
            } else if t.is_ident("drop")
                && code.get(i + 1).is_some_and(|x| x.is_punct('('))
                && code.get(i + 3).is_some_and(|x| x.is_punct(')'))
            {
                if let Some(v) = code.get(i + 2) {
                    held.retain(|h| h.var.as_deref() != Some(v.text.as_str()));
                }
            }
            // Call expansion (includes unresolved `.lock()` by name).
            if !held.is_empty()
                && t.kind == TokenKind::Ident
                && code.get(i + 1).is_some_and(|x| x.is_punct('('))
                && (i == 0 || !code[i - 1].is_ident("fn"))
            {
                let resolved_recv =
                    i > 0 && is_lock_call(code, i - 1) && !resolve(f.file, code, i - 1).is_empty();
                if !resolved_recv {
                    if let Some(set) = locks_by_name.get(&t.text) {
                        for &n in set {
                            edge(&held, n, t.line, &mut edges);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // Cycle detection: one finding per nontrivial strongly-connected
    // component, anchored at the lexically-last edge inside it.
    let mut adj = vec![Vec::new(); nodes.len()];
    for &(a, b) in edges.keys() {
        adj[a].push(b);
    }
    let mut out = Vec::new();
    for scc in tarjan_sccs(&adj) {
        if scc.len() < 2 {
            continue;
        }
        let in_scc: HashSet<usize> = scc.iter().copied().collect();
        let mut names: Vec<&str> =
            scc.iter().map(|&n| nodes[n].as_str()).collect::<Vec<_>>();
        names.sort_unstable();
        let site = edges
            .iter()
            .filter(|((a, b), _)| in_scc.contains(a) && in_scc.contains(b))
            .map(|(_, site)| site)
            .max_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
        let Some((path, line)) = site else { continue };
        out.push(Finding {
            rule: "lock_order",
            path: path.clone(),
            line: *line,
            message: format!(
                "lock-order cycle among {{{}}}: acquisition order must be globally consistent \
                 (see the rank table in util::sync)",
                names.join(", ")
            ),
        });
    }
    out
}

/// Is the statement containing token `at` a `let` binding, and to which
/// variable? Scans back to the nearest statement boundary.
fn statement_binding(code: &[Token], lo: usize, at: usize) -> (bool, Option<String>) {
    let mut k = at;
    while k > lo {
        k -= 1;
        let t = &code[k];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            return (false, None);
        }
        if t.is_ident("let") {
            let mut v = k + 1;
            if code.get(v).is_some_and(|t| t.is_ident("mut")) {
                v += 1;
            }
            let var = code.get(v).filter(|t| t.kind == TokenKind::Ident).map(|t| t.text.clone());
            return (true, var);
        }
    }
    (false, None)
}

// ---------------------------------------------------------------------------
// float_order_discipline

/// Slice/iterator sinks whose closure argument is an `Ordering`
/// comparator. Key-extraction sinks (`sort_by_key`, `min_by_key`, …) are
/// exempt: their closures return keys, not comparisons.
const COMPARATOR_SINKS: [&str; 5] =
    ["sort_by", "sort_unstable_by", "binary_search_by", "min_by", "max_by"];

/// In the numeric core (`src/select/`, `src/stats/`; test modules
/// excluded), float ordering must go through a total order:
/// `f64::total_cmp` or the `util::fkey` key maps. Two shapes are
/// findings: any `.partial_cmp(` call (its `unwrap()`/`unwrap_or(..)`
/// recoveries silently misplace NaN), and raw relational operators
/// (`<`, `>`, `<=`, `>=`, `==`, `!=`) inside a comparator closure passed
/// directly to a `sort_by`-family sink. Raw comparisons *outside*
/// comparator closures stay legal — IEEE semantics (`lo < hi`
/// convergence checks, NaN-propagating guards) are load-bearing there.
pub(crate) fn float_order_discipline(ft: &FileTokens) -> Vec<Finding> {
    let p = norm(&ft.file.path);
    if !(p.contains("src/select/") || p.contains("src/stats/")) {
        return Vec::new();
    }
    let limit = cfg_test_start(&ft.code);
    let code = &ft.code[..limit];
    let mut out = Vec::new();
    for i in 0..code.len() {
        if code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("partial_cmp"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
        {
            out.push(mk(
                "float_order_discipline",
                ft.file,
                code[i + 1].line,
                "partial_cmp is not a total order over floats (NaN breaks it); \
                 compare with total_cmp or a util::fkey key"
                    .to_string(),
            ));
        }
        // `sink(|a, b| …)` — a closure literal in argument position.
        let sink = code[i].kind == TokenKind::Ident
            && COMPARATOR_SINKS.contains(&code[i].text.as_str())
            && code.get(i + 1).is_some_and(|t| t.is_punct('('))
            && code.get(i + 2).is_some_and(|t| t.is_punct('|'));
        if !sink {
            continue;
        }
        let close = match_paren(code, i + 1);
        let Some(params_end) = (i + 3..close).find(|&j| code[j].is_punct('|')) else { continue };
        for k in params_end + 1..close {
            let t = &code[k];
            if t.kind != TokenKind::Punct {
                continue;
            }
            let c = t.text.chars().next().unwrap_or(' ');
            let punct_at = |j: usize| -> char {
                match code.get(j) {
                    Some(t) if t.kind == TokenKind::Punct => t.text.chars().next().unwrap_or(' '),
                    _ => ' ',
                }
            };
            let prev = if k > 0 { punct_at(k - 1) } else { ' ' };
            let next = punct_at(k + 1);
            // Raw relational operator, with arrows (`->`, `=>`), paths
            // (`::<`), shifts and compound assignment shapes filtered by
            // their neighbor characters.
            let raw = match c {
                '<' | '>' => {
                    !matches!(prev, '-' | '=' | ':' | '<' | '>') && !matches!(next, '<' | '>')
                }
                '=' => next == '=' && !matches!(prev, '=' | '!' | '<' | '>'),
                '!' => next == '=',
                _ => false,
            };
            if raw {
                out.push(mk(
                    "float_order_discipline",
                    ft.file,
                    t.line,
                    format!(
                        "raw `{}` comparison inside a `{}` comparator closure; \
                         use total_cmp or a util::fkey key for a total order",
                        if next == '=' && (c == '<' || c == '>' || c == '=' || c == '!') {
                            format!("{c}=")
                        } else {
                            c.to_string()
                        },
                        code[i].text
                    ),
                ));
                break;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// cancellation_discipline

/// Entry points whose call trees carry a cooperative-cancel hook.
const CANCEL_ROOTS: [&str; 2] = ["order_statistic", "solve_group"];

/// Functions allowed to run probe loops without polling the hook. Every
/// entry is itself checked: an entry whose function no longer exists in
/// the call tree, or which has since grown a poll, is a stale-registry
/// finding.
pub const CANCEL_EXEMPT: [(&str, &str); 6] = [
    (
        "bisect_resolve",
        "exact-fixup tail: a handful of probes after convergence, hard-capped by MAX_STEPS; \
         callers poll at their own pass boundaries",
    ),
    ("quickselect", "download-based single pass: no fused passes after the copy"),
    ("bfprt", "download-based single pass: no fused passes after the copy"),
    ("sort_select_f64", "download-based single pass: no fused passes after the copy"),
    ("sort_select_f32", "download-based single pass: no fused passes after the copy"),
    ("fixed_pivot_select", "download-based single pass: no fused passes after the copy"),
];

/// The pass-primitive method names. A function *named* like a primitive
/// (an `Evaluator` impl, or the sharded group's fan-out) IS the pass
/// implementation: any loop inside it — shard fan-out, chunked ladder
/// launches — runs *within* one logical pass, so the boundary the rule
/// polices lies between its invocations, which the rule checks in every
/// caller.
const PASS_PRIMITIVES: [&str; 3] = ["probe", "probe_many", "interval"];

fn span_polls_cancel(code: &[Token], span: (usize, usize)) -> bool {
    (span.0..=span.1).any(|k| {
        code[k].is_ident("cancel") && code.get(k + 1).is_some_and(|t| t.is_punct('('))
    })
}

/// Every pass loop — a `loop`/`while`/`for` whose body issues fused
/// reductions (`.probe(`, `.probe_many(`, `.interval(`) — in a function
/// reachable from a cancel root (`order_statistic`, `solve_group`) must
/// poll the cancel hook (`cancel()`), so deadline aborts land at pass
/// boundaries instead of after an unbounded pass sequence. Only the
/// outermost pass loop per nest is checked: pass boundaries are top-level
/// iterations, and inner loops run *within* a pass by design. Functions
/// named like the primitives themselves ([`PASS_PRIMITIVES`]) are the
/// pass *implementations* — their internal fan-out loops are one pass —
/// and functions in [`CANCEL_EXEMPT`] are skipped, with the registry
/// itself checked for staleness. The rule arms only when a root function
/// is present in the scanned set, so fixture scans stay quiet.
pub(crate) fn cancellation_discipline(files: &[FileTokens], cg: &CallGraph) -> Vec<Finding> {
    if CANCEL_ROOTS.iter().all(|r| cg.ids_named(r).is_empty()) {
        return Vec::new();
    }
    let reach = cg.reachable_from(&CANCEL_ROOTS);
    let mut out = Vec::new();
    let issues_pass = |code: &[Token], span: (usize, usize)| {
        (span.0..=span.1).any(|k| {
            code[k].is_punct('.')
                && code.get(k + 1).is_some_and(|t| {
                    t.is_ident("probe") || t.is_ident("probe_many") || t.is_ident("interval")
                })
                && code.get(k + 2).is_some_and(|t| t.is_punct('('))
        })
    };
    for (id, f) in cg.fns.iter().enumerate() {
        if f.in_test || !reach[id] {
            continue;
        }
        if CANCEL_EXEMPT.iter().any(|(n, _)| *n == f.name)
            || PASS_PRIMITIVES.contains(&f.name.as_str())
        {
            continue;
        }
        let code = &files[f.file].code;
        let mut i = f.body.0 + 1;
        while i < f.body.1 {
            let heads_loop =
                code[i].is_ident("loop") || code[i].is_ident("while") || code[i].is_ident("for");
            if !heads_loop {
                i += 1;
                continue;
            }
            // Loop body: next `{` outside the header's parens/brackets.
            let mut j = i + 1;
            let mut depth = 0usize;
            let mut open = None;
            while j <= f.body.1 {
                let t = &code[j];
                if t.is_punct('(') || t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(')') || t.is_punct(']') {
                    depth = depth.saturating_sub(1);
                } else if depth == 0 && t.is_punct('{') {
                    open = Some(j);
                    break;
                } else if depth == 0 && t.is_punct(';') {
                    break;
                }
                j += 1;
            }
            let Some(open) = open else {
                i += 1;
                continue;
            };
            let end = match_brace(code, open);
            if issues_pass(code, (open, end)) && !span_polls_cancel(code, (open, end)) {
                out.push(mk(
                    "cancellation_discipline",
                    files[f.file].file,
                    code[i].line,
                    format!(
                        "pass loop in `{}` (reachable from order_statistic/solve_group) issues \
                         fused reductions without polling the cancel hook",
                        f.name
                    ),
                ));
            }
            i = end + 1;
        }
    }
    for (name, _) in CANCEL_EXEMPT {
        for &id in cg.ids_named(name) {
            let f = &cg.fns[id];
            if f.in_test {
                continue;
            }
            if !reach[id] {
                out.push(mk(
                    "cancellation_discipline",
                    files[f.file].file,
                    f.line,
                    format!(
                        "`{name}` is exempt in the cancellation registry but is no longer \
                         reachable from a cancel root; remove the stale entry"
                    ),
                ));
            } else if span_polls_cancel(&files[f.file].code, f.body) {
                out.push(mk(
                    "cancellation_discipline",
                    files[f.file].file,
                    f.line,
                    format!(
                        "`{name}` is exempt in the cancellation registry but now polls the \
                         cancel hook; remove the stale entry"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// error_discipline

/// No `.unwrap()` / `.expect(..)` / `panic!` / `unreachable!` in the
/// worker-path directories (`src/coordinator/`, `src/runtime/`,
/// `src/select/`, `src/cluster/`; test modules excluded): a panic there
/// rides the fault-isolation machinery at best and kills a worker at
/// worst, and either way turns a query error into a process-level event.
/// Fallible paths return `crate::Error`. The escape hatch is a justified
/// suppression pragma on the site — the `unwrap_or_*` family and
/// `assert!` invariant checks are not findings.
pub(crate) fn error_discipline(ft: &FileTokens) -> Vec<Finding> {
    let p = norm(&ft.file.path);
    if !(p.contains("src/coordinator/")
        || p.contains("src/runtime/")
        || p.contains("src/select/")
        || p.contains("src/cluster/"))
    {
        return Vec::new();
    }
    let limit = cfg_test_start(&ft.code);
    let code = &ft.code[..limit];
    let mut out = Vec::new();
    for i in 0..code.len() {
        if code[i].is_punct('.')
            && code
                .get(i + 1)
                .is_some_and(|t| t.is_ident("unwrap") || t.is_ident("expect"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
        {
            out.push(mk(
                "error_discipline",
                ft.file,
                code[i + 1].line,
                format!(
                    ".{}() can panic on a worker path; return a crate::Error or justify a \
                     suppression",
                    code[i + 1].text
                ),
            ));
        } else if (code[i].is_ident("panic") || code[i].is_ident("unreachable"))
            && code.get(i + 1).is_some_and(|t| t.is_punct('!'))
        {
            out.push(mk(
                "error_discipline",
                ft.file,
                code[i].line,
                format!(
                    "{}! aborts the worker thread; return a crate::Error or justify a \
                     suppression",
                    code[i].text
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// atomic_ordering

const ATOMIC_OPS: [&str; 7] =
    ["fetch_add", "fetch_sub", "fetch_max", "fetch_min", "store", "load", "swap"];

/// Every access to a `Metrics` `AtomicU64` counter must use
/// `Ordering::Relaxed`. The counters are statistical — nothing
/// synchronizes *through* them — so an `Acquire`/`Release`/`SeqCst`
/// access either signals a misunderstanding (someone thinks a counter
/// publishes data) or buys fence traffic on the hot path for nothing.
/// The counter-name set is read from the `Metrics` struct declaration in
/// `coordinator/metrics.rs` (any visibility; the histogram array is out
/// of scope), and accesses are matched tree-wide, tests included.
pub(crate) fn atomic_ordering(files: &[FileTokens]) -> Vec<Finding> {
    let mut counters: HashSet<String> = HashSet::new();
    for ft in files {
        if !norm(&ft.file.path).ends_with("coordinator/metrics.rs") {
            continue;
        }
        if let Some(fields) = struct_fields(&ft.code, "Metrics") {
            counters.extend(
                fields.iter().filter(|f| f.ty == "AtomicU64").map(|f| f.name.clone()),
            );
        }
    }
    if counters.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    let is_op = |t: &Token| t.kind == TokenKind::Ident && ATOMIC_OPS.contains(&t.text.as_str());
    for ft in files {
        let code = &ft.code;
        for i in 0..code.len() {
            let hit = code[i].is_punct('.')
                && code
                    .get(i + 1)
                    .is_some_and(|t| t.kind == TokenKind::Ident && counters.contains(&t.text))
                && code.get(i + 2).is_some_and(|t| t.is_punct('.'))
                && code.get(i + 3).is_some_and(is_op)
                && code.get(i + 4).is_some_and(|t| t.is_punct('('));
            if !hit {
                continue;
            }
            let close = match_paren(code, i + 4);
            if !(i + 4..=close).any(|k| code[k].is_ident("Relaxed")) {
                out.push(mk(
                    "atomic_ordering",
                    ft.file,
                    code[i + 3].line,
                    format!(
                        "Metrics counter `{}` must use Ordering::Relaxed — counters are \
                         statistical, nothing synchronizes through them",
                        code[i + 1].text
                    ),
                ));
            }
        }
    }
    out
}
