//! Minimal Rust tokenizer for the in-repo invariant lint.
//!
//! The crate is offline and dependency-free, so `crate::analysis` cannot
//! lean on syn/proc-macro2. This module lexes just enough Rust to make
//! token-sequence rules sound: string and char literals (so braces and
//! keywords inside them are invisible to the rules), line and nested
//! block comments (kept as tokens — the pragma engine reads them),
//! identifiers, numbers, lifetimes, and single-character punctuation.
//! There is no parse tree; every rule in `analysis::rules` works on token
//! sequences plus balanced-delimiter spans.

/// What a [`Token`] is. `Punct` is always a single character.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    LineComment,
    BlockComment,
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct && self.text.chars().next() == Some(c)
    }

    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokenKind::LineComment | TokenKind::BlockComment)
    }
}

/// Lex `src` into a token stream (comments included).
pub fn tokenize(src: &str) -> Vec<Token> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1 }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn run(mut self) -> Vec<Token> {
        let mut out = Vec::new();
        while let Some(c) = self.peek(0) {
            let line = self.line;
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                out.push(self.line_comment(line));
            } else if c == '/' && self.peek(1) == Some('*') {
                out.push(self.block_comment(line));
            } else if c == '"' {
                out.push(self.string(line));
            } else if c == '\'' {
                out.push(self.quote(line));
            } else if c.is_ascii_digit() {
                out.push(self.number(line));
            } else if c == '_' || c.is_alphabetic() {
                out.push(self.ident_or_literal(line));
            } else {
                self.bump();
                out.push(Token { kind: TokenKind::Punct, text: c.to_string(), line });
            }
        }
        out
    }

    fn line_comment(&mut self, line: u32) -> Token {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        Token { kind: TokenKind::LineComment, text, line }
    }

    fn block_comment(&mut self, line: u32) -> Token {
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth = depth.saturating_sub(1);
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        Token { kind: TokenKind::BlockComment, text, line }
    }

    /// `"..."` with `\x` escapes (each escape skips exactly one char,
    /// which is enough to never terminate on an escaped quote).
    fn string(&mut self, line: u32) -> Token {
        let mut text = String::from('"');
        self.bump();
        while let Some(c) = self.bump() {
            text.push(c);
            if c == '\\' {
                if let Some(e) = self.bump() {
                    text.push(e);
                }
            } else if c == '"' {
                break;
            }
        }
        Token { kind: TokenKind::Str, text, line }
    }

    /// `r"…"`, `r#"…"#` (any hash count): ends only on `"` followed by
    /// the opening hash count.
    fn raw_string(&mut self, line: u32, prefix: &str) -> Token {
        let mut text = String::from(prefix);
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            text.push('#');
            self.bump();
        }
        text.push('"');
        self.bump();
        while let Some(c) = self.bump() {
            text.push(c);
            if c == '"' && (0..hashes).all(|k| self.peek(k) == Some('#')) {
                for _ in 0..hashes {
                    text.push('#');
                    self.bump();
                }
                break;
            }
        }
        Token { kind: TokenKind::Str, text, line }
    }

    /// Disambiguate `'x'` / `'\n'` (char literal) from `'a` / `'static`
    /// (lifetime): an escape or a close-quote two ahead means char.
    fn quote(&mut self, line: u32) -> Token {
        self.bump();
        if self.peek(0) == Some('\\') || self.peek(1) == Some('\'') {
            let mut text = String::from('\'');
            while let Some(c) = self.bump() {
                text.push(c);
                if c == '\\' {
                    if let Some(e) = self.bump() {
                        text.push(e);
                    }
                } else if c == '\'' {
                    break;
                }
            }
            Token { kind: TokenKind::Char, text, line }
        } else {
            let mut text = String::from('\'');
            while let Some(c) = self.peek(0) {
                if c == '_' || c.is_alphanumeric() {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
            Token { kind: TokenKind::Lifetime, text, line }
        }
    }

    fn number(&mut self, line: u32) -> Token {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_ascii_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else if c == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                text.push(c);
                self.bump();
            } else if (c == '+' || c == '-')
                && !text.starts_with("0x")
                && matches!(text.chars().last(), Some('e' | 'E'))
                && self.peek(1).is_some_and(|d| d.is_ascii_digit())
            {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        Token { kind: TokenKind::Num, text, line }
    }

    /// An identifier, unless it turns out to prefix a string/char literal
    /// (`r"…"`, `r#"…"#`, `b"…"`, `br"…"`, `b'…'`).
    fn ident_or_literal(&mut self, line: u32) -> Token {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '_' || c.is_alphanumeric() {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        let raw_str_follows = || {
            let mut k = 0;
            while self.peek(k) == Some('#') {
                k += 1;
            }
            self.peek(k) == Some('"')
        };
        match (text.as_str(), self.peek(0)) {
            ("r" | "br" | "rb", Some('"' | '#')) if raw_str_follows() => {
                self.raw_string(line, &text)
            }
            ("b", Some('"')) => {
                let mut t = self.string(line);
                t.text.insert(0, 'b');
                t
            }
            ("b", Some('\'')) => {
                let mut t = self.quote(line);
                t.text.insert(0, 'b');
                t
            }
            _ => Token { kind: TokenKind::Ident, text, line },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let ts = kinds("let x = 1_000 + 0xFF * 1.5e-3;");
        assert_eq!(ts[0], (TokenKind::Ident, "let".into()));
        assert_eq!(ts[1], (TokenKind::Ident, "x".into()));
        assert_eq!(ts[3], (TokenKind::Num, "1_000".into()));
        assert_eq!(ts[5], (TokenKind::Num, "0xFF".into()));
        assert_eq!(ts[7], (TokenKind::Num, "1.5e-3".into()));
        assert_eq!(ts[8], (TokenKind::Punct, ";".into()));
    }

    #[test]
    fn strings_hide_their_contents() {
        let ts = kinds(r#"a("Instant::now() } \" quote", 'x', '\n')"#);
        assert!(!ts.iter().any(|(k, t)| *k == TokenKind::Punct && t == "}"));
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokenKind::Str).count(), 1);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokenKind::Char).count(), 2);
        assert!(!ts.iter().any(|(k, t)| *k == TokenKind::Ident && t == "Instant"));
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let src = "x(r#\"inner \" quote and }\"#, b\"bytes\", br\"raw\")";
        let ts = kinds(src);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokenKind::Str).count(), 3);
        // nothing inside the raw string leaked out as punctuation
        assert!(!ts.iter().any(|(k, t)| *k == TokenKind::Punct && t == "}"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ts = kinds("fn f<'a>(x: &'a str) -> char { '_' }");
        assert!(ts.iter().any(|(k, t)| *k == TokenKind::Lifetime && t == "'a"));
        assert!(ts.iter().any(|(k, t)| *k == TokenKind::Char && t == "'_'"));
    }

    #[test]
    fn comments_carry_lines_and_nesting() {
        let src = "a\n// one\n/* two\n /* nested */ still */\nb";
        let ts = tokenize(src);
        let comment_lines: Vec<(TokenKind, u32)> =
            ts.iter().filter(|t| t.is_comment()).map(|t| (t.kind, t.line)).collect();
        assert_eq!(comment_lines, vec![(TokenKind::LineComment, 2), (TokenKind::BlockComment, 3)]);
        assert_eq!(ts.last().map(|t| (t.text.as_str(), t.line)), Some(("b", 5)));
    }
}
