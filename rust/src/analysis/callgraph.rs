//! Shared structural layer for the semantic rules: item/function/block
//! spans over the token stream, per-function call sets, and a reusable
//! name-keyed call graph with transitive closure.
//!
//! This is the dataflow-lite substrate the PR 7 rules grew toward — the
//! per-function lock-set fixpoint originally buried in `lock_order` now
//! rides [`CallGraph::fixpoint_union`], and the reachability queries the
//! cancellation rule needs ride [`CallGraph::reachable_from`]. Resolution
//! is by bare function name across every scanned file (no paths, no
//! receiver types), which over-approximates: a call `probe(..)` reaches
//! every function named `probe` anywhere in the tree. For lint purposes
//! an over-approximation errs toward reporting, which is the safe side.

use std::collections::{BTreeSet, HashMap};

use super::tokenizer::{Token, TokenKind};
use super::SourceFile;

/// One scanned file with its comment-stripped token stream (rules never
/// match inside comments; the pragma engine reads them separately).
pub(crate) struct FileTokens<'a> {
    pub file: &'a SourceFile,
    pub code: Vec<Token>,
}

pub(crate) fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

pub(crate) fn file_stem(path: &str) -> String {
    let p = norm(path);
    let base = p.rsplit('/').next().unwrap_or(&p);
    base.strip_suffix(".rs").unwrap_or(base).to_string()
}

/// Index of the matching `}` for the `{` at `open` (end of stream if
/// unbalanced — strings/comments are already opaque single tokens).
pub(crate) fn match_brace(code: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return k;
            }
        }
    }
    code.len().saturating_sub(1)
}

/// Index of the matching `)` for the `(` at `open`.
pub(crate) fn match_paren(code: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return k;
            }
        }
    }
    code.len().saturating_sub(1)
}

pub(crate) struct FnSpan {
    pub name: String,
    /// Line of the `fn` name token.
    pub line: u32,
    /// Token range of the body `{ … }` inclusive; `None` for bodyless
    /// trait-method declarations.
    pub body: Option<(usize, usize)>,
}

/// Every `fn name …` in the stream, nested functions included (their
/// spans overlap; innermost wins for enclosing-fn lookup).
pub(crate) fn fn_spans(code: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let heads_fn = code[i].is_ident("fn")
            && code.get(i + 1).is_some_and(|t| t.kind == TokenKind::Ident);
        if !heads_fn {
            i += 1;
            continue;
        }
        let name = code[i + 1].text.clone();
        let line = code[i + 1].line;
        let mut j = i + 2;
        let mut depth = 0usize; // () and [] nesting inside the signature
        let mut body = None;
        while j < code.len() {
            let t = &code[j];
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth = depth.saturating_sub(1);
            } else if depth == 0 && t.is_punct('{') {
                body = Some((j, match_brace(code, j)));
                break;
            } else if depth == 0 && t.is_punct(';') {
                break;
            }
            j += 1;
        }
        out.push(FnSpan { name, line, body });
        i += 2;
    }
    out
}

pub(crate) fn enclosing_fn<'a>(spans: &'a [FnSpan], idx: usize) -> Option<&'a FnSpan> {
    spans
        .iter()
        .filter(|s| s.body.is_some_and(|(b0, b1)| idx >= b0 && idx <= b1))
        .max_by_key(|s| s.body.map(|(b0, _)| b0))
}

/// First token of the file's `#[cfg(test)]` region (end of stream when
/// absent): the conventional cut between library code and its test module.
pub(crate) fn cfg_test_start(code: &[Token]) -> usize {
    for i in 0..code.len() {
        if code[i].is_punct('#')
            && code.get(i + 1).is_some_and(|t| t.is_punct('['))
            && code.get(i + 2).is_some_and(|t| t.is_ident("cfg"))
            && code.get(i + 3).is_some_and(|t| t.is_punct('('))
            && code.get(i + 4).is_some_and(|t| t.is_ident("test"))
        {
            return i;
        }
    }
    code.len()
}

pub(crate) fn in_region(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| idx > a && idx < b)
}

/// One call site inside a function body: the callee name (bare — method
/// calls and free calls alike) and the token index of its ident.
pub(crate) struct Call {
    pub name: String,
    pub at: usize,
}

/// One function with a body, as a call-graph node.
pub(crate) struct FnNode {
    /// Index into the scanned file slice.
    pub file: usize,
    pub name: String,
    /// Line of the `fn` name token.
    pub line: u32,
    /// Token range of the body `{ … }` inclusive.
    pub body: (usize, usize),
    /// Body starts at or after the file's `#[cfg(test)]` cut.
    pub in_test: bool,
    /// Every `ident (`-shaped call site in the body, in order.
    pub calls: Vec<Call>,
}

/// Cross-file call graph, name-keyed: an edge `f → g` exists when f's
/// body contains a call site named like any function g in the scan.
pub(crate) struct CallGraph {
    pub fns: Vec<FnNode>,
    by_name: HashMap<String, Vec<usize>>,
}

impl CallGraph {
    pub fn build(files: &[FileTokens]) -> CallGraph {
        let mut fns = Vec::new();
        for (fidx, ft) in files.iter().enumerate() {
            let code = &ft.code;
            let test_at = cfg_test_start(code);
            for s in fn_spans(code) {
                let Some(body) = s.body else { continue };
                let mut calls = Vec::new();
                for i in body.0..=body.1 {
                    if code[i].kind == TokenKind::Ident
                        && code.get(i + 1).is_some_and(|t| t.is_punct('('))
                        && (i == 0 || !code[i - 1].is_ident("fn"))
                    {
                        calls.push(Call { name: code[i].text.clone(), at: i });
                    }
                }
                fns.push(FnNode {
                    file: fidx,
                    name: s.name,
                    line: s.line,
                    body,
                    in_test: body.0 >= test_at,
                    calls,
                });
            }
        }
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        for (id, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(id);
        }
        CallGraph { fns, by_name }
    }

    /// Node ids of every function with the given name (empty if none).
    pub fn ids_named(&self, name: &str) -> &[usize] {
        self.by_name.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Per-node reachability from the named roots through the call edges
    /// (the roots themselves included).
    pub fn reachable_from(&self, roots: &[&str]) -> Vec<bool> {
        let mut reach = vec![false; self.fns.len()];
        let mut queue: Vec<usize> = Vec::new();
        for r in roots {
            for &id in self.ids_named(r) {
                if !reach[id] {
                    reach[id] = true;
                    queue.push(id);
                }
            }
        }
        while let Some(id) = queue.pop() {
            for call in &self.fns[id].calls {
                for &cid in self.ids_named(&call.name) {
                    if !reach[cid] {
                        reach[cid] = true;
                        queue.push(cid);
                    }
                }
            }
        }
        reach
    }

    /// Name-keyed union fixpoint: seed every function with a direct fact
    /// set, then propagate callee sets to callers until stable (same-named
    /// functions share one accumulator, matching the by-name resolution).
    /// `keep_call` filters call sites before expansion — e.g. `lock_order`
    /// drops sites it already resolved as field acquisitions.
    pub fn fixpoint_union<D, K>(&self, direct: D, keep_call: K) -> HashMap<String, BTreeSet<usize>>
    where
        D: Fn(&FnNode) -> BTreeSet<usize>,
        K: Fn(&FnNode, &Call) -> bool,
    {
        let mut by_name: HashMap<String, BTreeSet<usize>> = HashMap::new();
        for f in &self.fns {
            by_name.entry(f.name.clone()).or_default().extend(direct(f));
        }
        for _ in 0..12 {
            let mut changed = false;
            for f in &self.fns {
                let mut add = BTreeSet::new();
                for call in &f.calls {
                    if keep_call(f, call) {
                        if let Some(set) = by_name.get(&call.name) {
                            add.extend(set.iter().copied());
                        }
                    }
                }
                let mine = by_name.entry(f.name.clone()).or_default();
                let before = mine.len();
                mine.extend(add);
                changed |= mine.len() != before;
            }
            if !changed {
                break;
            }
        }
        by_name
    }
}

pub(crate) fn tarjan_sccs(adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    struct State<'a> {
        adj: &'a [Vec<usize>],
        index: Vec<Option<u32>>,
        low: Vec<u32>,
        on_stack: Vec<bool>,
        stack: Vec<usize>,
        next: u32,
        out: Vec<Vec<usize>>,
    }
    fn go(st: &mut State, v: usize) {
        st.index[v] = Some(st.next);
        st.low[v] = st.next;
        st.next += 1;
        st.stack.push(v);
        st.on_stack[v] = true;
        let neighbors = st.adj[v].clone();
        for w in neighbors {
            if st.index[w].is_none() {
                go(st, w);
                st.low[v] = st.low[v].min(st.low[w]);
            } else if st.on_stack[w] {
                st.low[v] = st.low[v].min(st.index[w].unwrap_or(0));
            }
        }
        if Some(st.low[v]) == st.index[v] {
            let mut scc = Vec::new();
            while let Some(w) = st.stack.pop() {
                st.on_stack[w] = false;
                scc.push(w);
                if w == v {
                    break;
                }
            }
            st.out.push(scc);
        }
    }
    let n = adj.len();
    let mut st = State {
        adj,
        index: vec![None; n],
        low: vec![0; n],
        on_stack: vec![false; n],
        stack: Vec::new(),
        next: 0,
        out: Vec::new(),
    };
    for v in 0..n {
        if st.index[v].is_none() {
            go(&mut st, v);
        }
    }
    st.out
}
