//! Machine-readable lint output.
//!
//! `cp-select lint --format json` emits one JSON object with a stable,
//! versioned schema so CI can turn findings into annotations and archive
//! them without scraping the text report:
//!
//! ```json
//! {
//!   "version": 1,
//!   "files": 74,
//!   "findings": [
//!     {"rule": "…", "file": "…", "line": 12, "message": "…", "suppressed": false}
//!   ],
//!   "suppressed": 4
//! }
//! ```
//!
//! `findings` carries active and pragma-suppressed findings merged, in
//! (file, line, rule) order, each tagged with `suppressed`; the top-level
//! `suppressed` count is the suppressed tally (so `findings` minus the
//! suppressed entries is what gates CI). The crate ships no JSON writer
//! ([`crate::util::json`] is read-only), so the escaping lives here.

use std::fmt::Write as _;

use super::{Finding, Report};

/// Schema version; bump on any field change.
pub const SCHEMA_VERSION: u32 = 1;

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

fn finding_into(out: &mut String, f: &Finding, suppressed: bool) {
    out.push_str("{\"rule\":\"");
    escape_into(out, f.rule);
    out.push_str("\",\"file\":\"");
    escape_into(out, &f.path);
    let _ = write!(out, "\",\"line\":{},\"message\":\"", f.line);
    escape_into(out, &f.message);
    let _ = write!(out, "\",\"suppressed\":{suppressed}}}");
}

/// Serialize a [`Report`] to the versioned JSON schema above.
pub fn to_json(report: &Report) -> String {
    let mut rows: Vec<(&Finding, bool)> = report
        .findings
        .iter()
        .map(|f| (f, false))
        .chain(report.suppressed.iter().map(|f| (f, true)))
        .collect();
    rows.sort_by(|(a, _), (b, _)| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"version\":{},\"files\":{},\"findings\":[",
        SCHEMA_VERSION, report.files
    );
    for (i, (f, suppressed)) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        finding_into(&mut out, f, *suppressed);
    }
    let _ = write!(out, "],\"suppressed\":{}}}", report.suppressed.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_specials() {
        let mut s = String::new();
        escape_into(&mut s, "a\"b\\c\nd\te\u{1}");
        assert_eq!(s, "a\\\"b\\\\c\\nd\\te\\u0001");
    }

    #[test]
    fn empty_report_round_trips() {
        let r = Report { findings: Vec::new(), files: 3, suppressed: Vec::new() };
        let j = to_json(&r);
        let v = crate::util::json::Json::parse(&j).expect("valid json");
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("files").unwrap().as_usize().unwrap(), 3);
        assert!(v.get("findings").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(v.get("suppressed").unwrap().as_usize().unwrap(), 0);
    }
}
