//! Dense linear-algebra substrate for the regression application.
//!
//! The paper's LMS/LTS search repeatedly solves tiny p×p systems (elemental
//! subsets) and one final least-squares refit. We implement column-major
//! dense matrices with Cholesky and Householder-QR solvers — no external
//! BLAS in this offline environment (DESIGN.md S17).

use crate::{invalid_arg, Result};

/// Dense column-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        if r == 0 {
            return Err(invalid_arg!("empty matrix"));
        }
        let c = rows[0].len();
        if rows.iter().any(|row| row.len() != c) {
            return Err(invalid_arg!("ragged rows"));
        }
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        Ok(m)
    }

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }

    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// y = A * x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let c = self.col(j);
            let xj = x[j];
            for (yi, &cij) in y.iter_mut().zip(c) {
                *yi += cij * xj;
            }
        }
        y
    }

    /// Gram matrix AᵀA (p×p) and Aᵀb, the normal equations.
    pub fn normal_eqs(&self, b: &[f64]) -> (Mat, Vec<f64>) {
        assert_eq!(b.len(), self.rows);
        let p = self.cols;
        let mut g = Mat::zeros(p, p);
        let mut atb = vec![0.0; p];
        for j in 0..p {
            let cj = self.col(j);
            atb[j] = cj.iter().zip(b).map(|(a, b)| a * b).sum();
            for k in j..p {
                let ck = self.col(k);
                let s: f64 = cj.iter().zip(ck).map(|(a, b)| a * b).sum();
                g[(j, k)] = s;
                g[(k, j)] = s;
            }
        }
        (g, atb)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[j * self.rows + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[j * self.rows + i]
    }
}

/// Solve the SPD system `A x = b` in place via Cholesky. Returns `None` if
/// `A` is not positive definite (within a tiny pivot tolerance).
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return None;
    }
    let mut l = a.clone();
    // factor: L L^T, lower triangle of l
    for j in 0..n {
        let mut d = l[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= 1e-300 {
            return None;
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        for i in j + 1..n {
            let mut s = l[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s / dj;
        }
    }
    // forward substitution: L y = b
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            y[i] -= l[(i, k)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
    // back substitution: L^T x = y
    for i in (0..n).rev() {
        for k in i + 1..n {
            y[i] -= l[(k, i)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
    Some(y)
}

/// Least-squares solve `min ||A x - b||` via Householder QR.
/// Works for rows >= cols; returns `None` on rank deficiency.
pub fn qr_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let (m, n) = (a.rows, a.cols);
    if m < n || b.len() != m {
        return None;
    }
    let mut r = a.clone();
    let mut rhs = b.to_vec();
    for j in 0..n {
        // Householder vector for column j
        let mut norm = 0.0;
        for i in j..m {
            norm += r[(i, j)] * r[(i, j)];
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            return None;
        }
        let alpha = if r[(j, j)] > 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m - j];
        v[0] = r[(j, j)] - alpha;
        for i in j + 1..m {
            v[i - j] = r[(i, j)];
        }
        let vtv: f64 = v.iter().map(|x| x * x).sum();
        if vtv < 1e-300 {
            return None;
        }
        r[(j, j)] = alpha;
        for i in j + 1..m {
            r[(i, j)] = 0.0;
        }
        // apply H = I - 2 v v^T / v^T v to remaining columns + rhs
        for k in j + 1..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * r[(i, k)];
            }
            let f = 2.0 * dot / vtv;
            for i in j..m {
                r[(i, k)] -= f * v[i - j];
            }
        }
        let mut dot = 0.0;
        for i in j..m {
            dot += v[i - j] * rhs[i];
        }
        let f = 2.0 * dot / vtv;
        for i in j..m {
            rhs[i] -= f * v[i - j];
        }
    }
    // back substitution on the n×n upper triangle
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = rhs[i];
        for k in i + 1..n {
            s -= r[(i, k)] * x[k];
        }
        if r[(i, i)].abs() < 1e-300 {
            return None;
        }
        x[i] = s / r[(i, i)];
    }
    Some(x)
}

/// Solve a small square system `A x = b` by partial-pivot Gaussian
/// elimination (used for p×p elemental fits, where A is not SPD).
pub fn gauss_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return None;
    }
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for i in col + 1..n {
            if m[(i, col)].abs() > m[(piv, col)].abs() {
                piv = i;
            }
        }
        if m[(piv, col)].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let t = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = t;
            }
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        for i in col + 1..n {
            let f = m[(i, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                m[(i, j)] -= f * m[(col, j)];
            }
            x[i] -= f * x[col];
        }
    }
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in i + 1..n {
            s -= m[(i, j)] * x[j];
        }
        x[i] = s / m[(i, i)];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]).unwrap();
        approx(&a.matvec(&[1.0, -1.0]), &[-1.0, -1.0, -1.0], 1e-12);
    }

    #[test]
    fn cholesky_solves_spd() {
        let a = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]).unwrap();
        let x = cholesky_solve(&a, &[8.0, 7.0]).unwrap();
        approx(&x, &[1.25, 1.5], 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap();
        assert!(cholesky_solve(&a, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn qr_least_squares_overdetermined() {
        // fit y = 2x + 1 exactly through 4 points
        let a = Mat::from_rows(&[
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![2.0, 1.0],
            vec![3.0, 1.0],
        ])
        .unwrap();
        let b = [1.0, 3.0, 5.0, 7.0];
        let x = qr_solve(&a, &b).unwrap();
        approx(&x, &[2.0, 1.0], 1e-10);
    }

    #[test]
    fn qr_matches_normal_equations() {
        // random-ish well-conditioned system
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let t = i as f64 * 0.37;
                vec![t.sin(), t.cos(), 1.0]
            })
            .collect();
        let a = Mat::from_rows(&rows).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64 * 0.11).cos()).collect();
        let x_qr = qr_solve(&a, &b).unwrap();
        let (g, atb) = a.normal_eqs(&b);
        let x_ne = cholesky_solve(&g, &atb).unwrap();
        approx(&x_qr, &x_ne, 1e-8);
    }

    #[test]
    fn gauss_solves_general() {
        let a = Mat::from_rows(&[vec![0.0, 2.0], vec![3.0, 1.0]]).unwrap();
        let x = gauss_solve(&a, &[4.0, 5.0]).unwrap();
        approx(&x, &[1.0, 2.0], 1e-12);
    }

    #[test]
    fn gauss_rejects_singular() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]).unwrap();
        assert!(gauss_solve(&a, &[1.0, 2.0]).is_none());
    }
}
