//! Minimal JSON parser for the artifact manifest.
//!
//! serde is unavailable in this offline build environment (DESIGN.md §7), so
//! the manifest (a small, trusted, machine-generated file) is parsed with a
//! ~200-line recursive-descent parser supporting the full JSON grammar.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Parse(format!("trailing characters at byte {} of JSON input", p.i)));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Parse(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 * 4096.0 {
            return Err(Error::Parse(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// Field access with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Parse(format!("missing field {key:?}")))
    }

    /// Optional field access (`null` counts as absent).
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => match m.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of JSON input".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("invalid literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(Error::Parse(format!(
                "unexpected character {:?} at byte {}",
                c as char, self.i
            ))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}', found {:?} at byte {}",
                        c as char, self.i
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']', found {:?} at byte {}",
                        c as char, self.i
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| Error::Parse("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Parse("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            self.i += 4;
                            // Surrogate pairs are not needed for the manifest;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(Error::Parse("bad escape".into())),
                    }
                }
                _ => {
                    // copy UTF-8 bytes verbatim
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| Error::Parse("invalid UTF-8 in string".into()))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number {txt:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 2,
          "entries": [
            {"kernel": "fused_objective", "flavor": "jnp", "dtype": "f64",
             "n": 4096, "p": null, "path": "a.hlo.txt",
             "inputs": [{"dtype": "f64", "shape": [4096]}]}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 2);
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("kernel").unwrap().as_str().unwrap(), "fused_objective");
        assert!(e.get_opt("p").is_none());
        let inp = &e.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[0].as_usize().unwrap(), 4096);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb\t\"c\" A""#).unwrap(), Json::Str("a\nb\t\"c\" A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
        assert!(Json::parse("{'single': 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"[[1,2],[3,[4,{"k":[]}]]]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn type_errors_are_descriptive() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.get("b").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }
}
