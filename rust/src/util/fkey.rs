//! Order-preserving integer keys for IEEE-754 floats.
//!
//! The radix-sort substrate (DESIGN.md S8) sorts floats by mapping them to
//! unsigned keys whose integer order equals the floats' total order: flip
//! all bits of negatives, flip only the sign bit of non-negatives. This is
//! the standard trick used by GPU radix sorts (Satish/Harris/Garland 2009,
//! the paper's reference [29]).
//!
//! NaNs sort above +inf (same as `f64::total_cmp`); -0.0 sorts below +0.0.

/// Map an `f32` to a `u32` whose unsigned order matches float total order.
#[inline(always)]
pub fn f32_key(v: f32) -> u32 {
    let b = v.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of [`f32_key`].
#[inline(always)]
pub fn key_f32(k: u32) -> f32 {
    let b = if k & 0x8000_0000 != 0 {
        k ^ 0x8000_0000
    } else {
        !k
    };
    f32::from_bits(b)
}

/// Map an `f64` to a `u64` whose unsigned order matches float total order.
#[inline(always)]
pub fn f64_key(v: f64) -> u64 {
    let b = v.to_bits();
    if b & 0x8000_0000_0000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000_0000_0000
    }
}

/// Inverse of [`f64_key`].
#[inline(always)]
pub fn key_f64(k: u64) -> f64 {
    let b = if k & 0x8000_0000_0000_0000 != 0 {
        k ^ 0x8000_0000_0000_0000
    } else {
        !k
    };
    f64::from_bits(b)
}

/// Total-order comparator for `f64` (delegates to the std total order).
#[inline(always)]
pub fn total_cmp_f64(a: &f64, b: &f64) -> std::cmp::Ordering {
    a.total_cmp(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_key_orders_like_total_cmp() {
        let vals = [
            f64::NEG_INFINITY,
            -1e300,
            -1.0,
            -1e-300,
            -0.0,
            0.0,
            1e-300,
            1.0,
            1e300,
            f64::INFINITY,
        ];
        for w in vals.windows(2) {
            assert!(f64_key(w[0]) < f64_key(w[1]), "{} !< {}", w[0], w[1]);
        }
        // except -0.0 vs 0.0 which are distinct keys but equal floats
        assert!(f64_key(-0.0) < f64_key(0.0));
    }

    #[test]
    fn f32_key_orders_like_total_cmp() {
        let vals = [
            f32::NEG_INFINITY,
            -3.3e38,
            -2.0,
            -0.0,
            0.0,
            5.0e-40,
            2.0,
            3.3e38,
            f32::INFINITY,
        ];
        for w in vals.windows(2) {
            assert!(f32_key(w[0]) <= f32_key(w[1]));
        }
    }

    #[test]
    fn keys_roundtrip() {
        for v in [-1234.5f64, -0.0, 0.0, 1e-9, 7.25, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(key_f64(f64_key(v)).to_bits(), v.to_bits());
        }
        for v in [-1234.5f32, -0.0, 0.0, 1e-9, 7.25] {
            assert_eq!(key_f32(f32_key(v)).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn nan_sorts_last() {
        assert!(f64_key(f64::NAN) > f64_key(f64::INFINITY));
    }

    #[test]
    fn random_pairs_consistent_with_total_cmp() {
        let mut s = 0x12345678u64;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            f64::from_bits(s & 0x7FEF_FFFF_FFFF_FFFF) * if s & 1 == 0 { 1.0 } else { -1.0 }
        };
        for _ in 0..10_000 {
            let (a, b) = (next(), next());
            let ka = f64_key(a).cmp(&f64_key(b));
            assert_eq!(ka, a.total_cmp(&b), "a={a} b={b}");
        }
    }
}
