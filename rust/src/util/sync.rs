//! Rank-ordered mutexes: the runtime half of the `lock_order` lint rule.
//!
//! The static pass in [`crate::analysis`] proves the *lexical* nesting of
//! `.lock()` scopes acyclic, but cannot see orders that only exist at
//! runtime (locks reached through trait objects, closures, or channels).
//! [`OrderedMutex`] closes that gap: every coordinator mutex carries a
//! rank, each thread keeps a stack of the ranks it holds, and acquiring
//! a lock whose rank is not strictly above the top of the stack panics —
//! in the thread that would have deadlocked, before it blocks. The check
//! is unconditional (not `debug_assert!`): the stress/chaos CI legs run
//! `--release`, and an O(1) compare against the stack top is noise next
//! to the lock itself.
//!
//! ## Rank table
//!
//! | rank | constant                | lock                                  |
//! |------|-------------------------|---------------------------------------|
//! | 10   | [`RANK_ADMISSION`]      | `service.admission` (token buckets)   |
//! | 20   | [`RANK_TENANT_DEPTH`]   | `metrics.tenant_depth`                |
//! | 25   | [`RANK_CLUSTER_REGISTRY`]| `cluster.registry` (worker slots)    |
//! | 30   | [`RANK_COST_MODEL_POOL`]| `gpu_model.inner` (shared cost model) |
//! | 40   | [`RANK_FAULT_SCRIPT`]   | `fault.state` (test fault script)     |
//! | 50   | [`RANK_VIRTUAL_CLOCK`]  | `clock.state` (virtual clock)         |
//!
//! The virtual clock is ranked last because everything may consult the
//! clock while holding its own lock, and the clock never calls out.

use std::cell::RefCell;
use std::fmt;
use std::mem::ManuallyDrop;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard};

pub const RANK_ADMISSION: u32 = 10;
pub const RANK_TENANT_DEPTH: u32 = 20;
pub const RANK_CLUSTER_REGISTRY: u32 = 25;
pub const RANK_COST_MODEL_POOL: u32 = 30;
pub const RANK_FAULT_SCRIPT: u32 = 40;
pub const RANK_VIRTUAL_CLOCK: u32 = 50;

thread_local! {
    /// Ranks (with lock names, for the panic message) this thread holds,
    /// in acquisition order.
    static HELD: RefCell<Vec<(u32, &'static str)>> = const { RefCell::new(Vec::new()) };
}

/// A mutex that enforces a global acquisition order by rank. Poisoning
/// is always recovered (the repo-wide `.lock()` idiom), so the guard
/// type never carries a `Result`.
pub struct OrderedMutex<T> {
    rank: u32,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T: fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("rank", &self.rank)
            .field("name", &self.name)
            .field("inner", &self.inner)
            .finish()
    }
}

impl<T> OrderedMutex<T> {
    pub fn new(rank: u32, name: &'static str, value: T) -> Self {
        OrderedMutex { rank, name, inner: Mutex::new(value) }
    }

    /// Acquire the lock. Panics if this thread already holds a lock of
    /// equal or higher rank — checked *before* blocking, so the inversion
    /// is reported by the thread that would have deadlocked.
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        HELD.with(|h| {
            if let Some(&(top, top_name)) = h.borrow().last() {
                assert!(
                    self.rank > top,
                    "lock-order violation: acquiring {} (rank {}) while holding {} (rank {})",
                    self.name,
                    self.rank,
                    top_name,
                    top
                );
            }
        });
        let guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        HELD.with(|h| h.borrow_mut().push((self.rank, self.name)));
        OrderedGuard { guard: ManuallyDrop::new(guard), rank: self.rank }
    }
}

/// Guard returned by [`OrderedMutex::lock`]; pops its rank from the
/// thread's held stack on drop.
pub struct OrderedGuard<'a, T> {
    guard: ManuallyDrop<MutexGuard<'a, T>>,
    rank: u32,
}

impl<'a, T> OrderedGuard<'a, T> {
    /// Block on `cv`, atomically releasing the mutex and re-acquiring it
    /// on wake. The rank entry stays on the held stack across the wait:
    /// rank-wise the lock never leaves this thread, which keeps
    /// wait-loops (`while !ready { g = g.wait(&cv) }`) order-correct.
    pub fn wait(mut self, cv: &Condvar) -> OrderedGuard<'a, T> {
        let rank = self.rank;
        // SAFETY: `self` is forgotten immediately after the take, so the
        // guard is dropped exactly once (inside cv.wait's re-acquire).
        let inner = unsafe { ManuallyDrop::take(&mut self.guard) };
        std::mem::forget(self);
        let inner = cv.wait(inner).unwrap_or_else(|e| e.into_inner());
        OrderedGuard { guard: ManuallyDrop::new(inner), rank }
    }

    /// Like [`wait`](Self::wait) but gives up after `dur`. Returns the
    /// re-acquired guard and whether the wait timed out. The rank entry
    /// stays on the held stack across the wait, same as `wait`.
    pub fn wait_timeout(
        mut self,
        cv: &Condvar,
        dur: std::time::Duration,
    ) -> (OrderedGuard<'a, T>, bool) {
        let rank = self.rank;
        // SAFETY: `self` is forgotten immediately after the take, so the
        // guard is dropped exactly once (inside cv.wait_timeout's
        // re-acquire).
        let inner = unsafe { ManuallyDrop::take(&mut self.guard) };
        std::mem::forget(self);
        let (inner, res) = cv
            .wait_timeout(inner, dur)
            .unwrap_or_else(|e| e.into_inner());
        (OrderedGuard { guard: ManuallyDrop::new(inner), rank }, res.timed_out())
    }
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(i) = held.iter().rposition(|&(r, _)| r == self.rank) {
                held.remove(i);
            }
        });
        // SAFETY: drop runs once; `wait` forgets `self` before this could.
        unsafe { ManuallyDrop::drop(&mut self.guard) };
    }
}

#[cfg(test)]
mod tests {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar};

    use super::*;

    #[test]
    fn in_order_nesting_is_fine() {
        let low = OrderedMutex::new(10, "low", 1u32);
        let high = OrderedMutex::new(20, "high", 2u32);
        {
            let a = low.lock();
            let mut b = high.lock();
            *b += *a;
        }
        // both ranks popped: re-acquiring from scratch still works
        assert_eq!(*high.lock(), 3);
        assert_eq!(*low.lock(), 1);
    }

    #[test]
    fn out_of_order_acquisition_panics() {
        let low = OrderedMutex::new(10, "low", ());
        let high = OrderedMutex::new(20, "high", ());
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _g = high.lock();
            let _bad = low.lock();
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-order violation"), "unexpected panic: {msg}");
        // the unwind released `high`; the correct order works afterwards
        let _a = low.lock();
        let _b = high.lock();
    }

    #[test]
    fn guard_survives_a_condvar_wait() {
        let shared = Arc::new((OrderedMutex::new(30, "flag", false), Condvar::new()));
        let peer = Arc::clone(&shared);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*peer;
            *m.lock() = true;
            cv.notify_one();
        });
        let (m, cv) = &*shared;
        let mut g = m.lock();
        while !*g {
            g = g.wait(cv);
        }
        // the rank is still held after the wait: a lower rank must panic
        let low = OrderedMutex::new(10, "late-low", ());
        assert!(catch_unwind(AssertUnwindSafe(|| {
            let _bad = low.lock();
        }))
        .is_err());
        drop(g);
        // ...and is released with the guard
        let _ok = low.lock();
        t.join().unwrap();
    }

    #[test]
    fn wait_timeout_reports_expiry_and_keeps_rank_protocol() {
        let shared = Arc::new((OrderedMutex::new(30, "slot", false), Condvar::new()));
        // nothing signals: the wait must expire and hand the guard back
        let (m, cv) = &*shared;
        let g = m.lock();
        let (g, timed_out) = g.wait_timeout(cv, std::time::Duration::from_millis(5));
        assert!(timed_out);
        assert!(!*g);
        drop(g);

        // a signalled wait returns before the (long) timeout
        let peer = Arc::clone(&shared);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*peer;
            *m.lock() = true;
            cv.notify_one();
        });
        let (m, cv) = &*shared;
        let mut g = m.lock();
        let mut expired = false;
        while !*g && !expired {
            let (g2, to) = g.wait_timeout(cv, std::time::Duration::from_secs(5));
            g = g2;
            expired = to;
        }
        assert!(*g, "condvar signal lost");
        // the rank was held across the timed wait and releases with the guard
        drop(g);
        let _ok = OrderedMutex::new(10, "after-low", ()).lock();
        t.join().unwrap();
    }
}
