//! Phase timers used to reproduce the paper's per-phase table rows
//! ("CP iterations", "copy_if", "Radix sort of z"; "copy to CPU",
//! "algorithm").

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named phase durations; phases may recur (durations add up).
#[derive(Debug, Clone, Default)]
pub struct PhaseTimer {
    phases: BTreeMap<&'static str, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under the given phase name.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed());
        out
    }

    pub fn record(&mut self, phase: &'static str, d: Duration) {
        *self.phases.entry(phase).or_default() += d;
    }

    pub fn get_ms(&self, phase: &str) -> f64 {
        self.phases
            .get(phase)
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }

    pub fn total_ms(&self) -> f64 {
        self.phases.values().map(|d| d.as_secs_f64() * 1e3).sum()
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.phases.iter().map(|(k, v)| (*k, v.as_secs_f64() * 1e3))
    }

    /// Merge another timer's phases into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.phases {
            *self.phases.entry(k).or_default() += *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut t = PhaseTimer::new();
        t.record("a", Duration::from_millis(2));
        t.record("a", Duration::from_millis(3));
        t.record("b", Duration::from_millis(1));
        assert!((t.get_ms("a") - 5.0).abs() < 1e-9);
        assert!((t.total_ms() - 6.0).abs() < 1e-9);
        assert_eq!(t.get_ms("missing"), 0.0);
    }

    #[test]
    fn time_closure_records_something() {
        let mut t = PhaseTimer::new();
        let v = t.time("work", || {
            // lint: allow(clock_discipline) — wall-clock self-test of the wall-clock instrument
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(t.get_ms("work") >= 1.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        a.record("x", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.record("x", Duration::from_millis(2));
        b.record("y", Duration::from_millis(4));
        a.merge(&b);
        assert!((a.get_ms("x") - 3.0).abs() < 1e-9);
        assert!((a.get_ms("y") - 4.0).abs() < 1e-9);
    }
}
