//! Shared low-level utilities: float ordering keys, compensated summation,
//! timers, rank-ordered mutexes, tiny JSON parser, and the dense
//! linear-algebra substrate.

pub mod fkey;
pub mod json;
pub mod kahan;
pub mod linalg;
pub mod sync;
pub mod timer;

pub use fkey::{f32_key, f64_key, key_f32, key_f64, total_cmp_f64};
pub use kahan::KahanSum;
pub use sync::{OrderedGuard, OrderedMutex};
pub use timer::{PhaseTimer, Stopwatch};

/// Round `n` up to the next power of two (n >= 1).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Integer part of (n+1)/2 — the paper's median index (1-based), `Med(x) =
/// x_([(n+1)/2])`.
pub fn median_rank(n: usize) -> usize {
    n.div_ceil(2)
}

/// The LTS trim count: h = [(n+p)/2] in Rousseeuw's formulation; the paper's
/// §VI uses h = (n+1)/2 for odd n and n/2 for even n (p folded elsewhere).
pub fn lts_h(n: usize) -> usize {
    if n % 2 == 1 {
        n.div_ceil(2)
    } else {
        n / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_rank_matches_paper_formula() {
        assert_eq!(median_rank(1), 1);
        assert_eq!(median_rank(2), 1);
        assert_eq!(median_rank(3), 2);
        assert_eq!(median_rank(4), 2);
        assert_eq!(median_rank(5), 3);
        assert_eq!(median_rank(8192), 4096);
    }

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(4096), 4096);
        assert_eq!(next_pow2(4097), 8192);
    }

    #[test]
    fn lts_h_parity() {
        assert_eq!(lts_h(5), 3);
        assert_eq!(lts_h(6), 3);
        assert_eq!(lts_h(101), 51);
    }
}
