//! Kahan–Babuška compensated summation.
//!
//! The paper (§V.D) discusses catastrophic loss of precision in `Σ|x_i - y|`
//! when single elements reach ~1e20. The device side addresses this with the
//! monotone log-transform (see `select::transform`); on the host side every
//! accumulation in the evaluators uses compensated summation so the CPU
//! oracle is trustworthy even on adversarial data.

/// Neumaier's improved Kahan summation.
#[derive(Debug, Clone, Copy, Default)]
pub struct KahanSum {
    sum: f64,
    comp: f64,
}

impl KahanSum {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline(always)]
    pub fn add(&mut self, v: f64) {
        let t = self.sum + v;
        if self.sum.abs() >= v.abs() {
            self.comp += (self.sum - t) + v;
        } else {
            self.comp += (v - t) + self.sum;
        }
        self.sum = t;
    }

    #[inline(always)]
    pub fn value(&self) -> f64 {
        self.sum + self.comp
    }
}

impl std::iter::FromIterator<f64> for KahanSum {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut k = KahanSum::new();
        for v in iter {
            k.add(v);
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_small_ints() {
        let k: KahanSum = (0..1000).map(|i| i as f64).collect();
        assert_eq!(k.value(), 499_500.0);
    }

    #[test]
    fn survives_large_cancellation() {
        // naive summation loses the 1.0 terms entirely
        let mut k = KahanSum::new();
        k.add(1e20);
        for _ in 0..1000 {
            k.add(1.0);
        }
        k.add(-1e20);
        assert_eq!(k.value(), 1000.0);
    }

    #[test]
    fn paper_scenario_outlier_1e20() {
        // f(y) = sum |x_i - y| with one 1e20 outlier and 1e5 unit terms:
        // naive f32/f64 summation would report the unit terms as 0.
        let mut k = KahanSum::new();
        k.add(1e20);
        for i in 0..100_000 {
            k.add(0.5 + (i % 7) as f64 * 0.1);
        }
        let bulk: f64 = (0..100_000).map(|i| 0.5 + (i % 7) as f64 * 0.1).sum();
        assert!((k.value() - (1e20 + bulk)).abs() <= 1e4); // vs ~6e4 bulk
    }
}
