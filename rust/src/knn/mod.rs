//! k-nearest-neighbor regression & classification via order statistics
//! (paper §VI, application 2).
//!
//! Instead of sorting the n distances per query, the k-th order statistic
//! `d_(k)` (found by the cutting plane in a handful of reductions) acts as
//! a neighborhood threshold; the prediction is then one thresholded
//! weighted reduction — the same ρ-function adaptation the paper describes
//! for Eq. (4). Device twin: kernels `dists` + `knn_weighted_sum`.

use crate::regression::MedianSelector;
use crate::{invalid_arg, Result};

/// A kNN model over host data (device variant in `examples/knn.rs`).
#[derive(Debug, Clone)]
pub struct KnnModel {
    /// Points, row-major n × p.
    pub x: Vec<Vec<f64>>,
    /// Regression targets (or class labels as f64 for classification).
    pub f: Vec<f64>,
}

impl KnnModel {
    pub fn new(x: Vec<Vec<f64>>, f: Vec<f64>) -> Result<Self> {
        if x.is_empty() || x.len() != f.len() {
            return Err(invalid_arg!("need equally many points and targets"));
        }
        let p = x[0].len();
        if x.iter().any(|r| r.len() != p) {
            return Err(invalid_arg!("ragged point dimensions"));
        }
        Ok(KnnModel { x, f })
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }

    /// Squared distances to a query (the device `dists` kernel).
    pub fn distances(&self, q: &[f64]) -> Vec<f64> {
        self.x
            .iter()
            .map(|row| {
                row.iter()
                    .zip(q)
                    .map(|(a, b)| {
                        let d = a - b;
                        d * d
                    })
                    .sum()
            })
            .collect()
    }

    /// Inverse-distance-weighted kNN regression: the k-th order statistic
    /// of the distances is the neighborhood radius; prediction is a single
    /// thresholded reduction (device `knn_weighted_sum` kernel).
    pub fn predict_regression(
        &self,
        q: &[f64],
        k: usize,
        selector: &mut dyn MedianSelector,
    ) -> Result<f64> {
        let d = self.distances(q);
        let t = self.threshold(&d, k, selector)?;
        let (mut swf, mut sw, mut count) = (0.0, 0.0, 0usize);
        for (&di, &fi) in d.iter().zip(&self.f) {
            if di <= t {
                let w = 1.0 / (1.0 + di);
                swf += w * fi;
                sw += w;
                count += 1;
            }
        }
        debug_assert!(count >= k);
        Ok(swf / sw)
    }

    /// Majority-vote classification over the selected neighborhood.
    pub fn predict_class(
        &self,
        q: &[f64],
        k: usize,
        selector: &mut dyn MedianSelector,
    ) -> Result<i64> {
        let d = self.distances(q);
        let t = self.threshold(&d, k, selector)?;
        let mut votes: std::collections::BTreeMap<i64, usize> = Default::default();
        for (&di, &fi) in d.iter().zip(&self.f) {
            if di <= t {
                *votes.entry(fi.round() as i64).or_default() += 1;
            }
        }
        Ok(votes
            .into_iter()
            .max_by_key(|&(_, c)| c)
            .map(|(label, _)| label)
            .expect("non-empty neighborhood"))
    }

    fn threshold(&self, d: &[f64], k: usize, selector: &mut dyn MedianSelector) -> Result<f64> {
        if k == 0 || k > self.n() {
            return Err(invalid_arg!("k={k} out of range for n={}", self.n()));
        }
        selector.order_statistic(d, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::HostSelector;
    use crate::stats::Rng;

    fn grid_model() -> KnnModel {
        // f(x) = 2 x0 + x1 on a grid
        let mut x = Vec::new();
        let mut f = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                let (a, b) = (i as f64 / 10.0, j as f64 / 10.0);
                x.push(vec![a, b]);
                f.push(2.0 * a + b);
            }
        }
        KnnModel::new(x, f).unwrap()
    }

    #[test]
    fn regression_approximates_smooth_function() {
        let m = grid_model();
        let mut sel = HostSelector::default();
        for q in [[0.55, 0.55], [1.0, 0.3], [1.77, 1.9]] {
            let pred = m.predict_regression(&q, 8, &mut sel).unwrap();
            let truth = 2.0 * q[0] + q[1];
            assert!((pred - truth).abs() < 0.15, "q={q:?} pred={pred} truth={truth}");
        }
    }

    #[test]
    fn neighborhood_contains_at_least_k() {
        let m = grid_model();
        let mut sel = HostSelector::default();
        let d = m.distances(&[0.5, 0.5]);
        for k in [1, 5, 40] {
            let t = sel.order_statistic(&d, k).unwrap();
            let inside = d.iter().filter(|&&x| x <= t).count();
            assert!(inside >= k, "k={k} inside={inside}");
        }
    }

    #[test]
    fn classification_two_blobs() {
        let mut rng = Rng::seeded(161);
        let mut x = Vec::new();
        let mut f = Vec::new();
        for _ in 0..100 {
            x.push(vec![rng.normal() * 0.5, rng.normal() * 0.5]);
            f.push(0.0);
            x.push(vec![5.0 + rng.normal() * 0.5, 5.0 + rng.normal() * 0.5]);
            f.push(1.0);
        }
        let m = KnnModel::new(x, f).unwrap();
        let mut sel = HostSelector::default();
        assert_eq!(m.predict_class(&[0.2, -0.1], 9, &mut sel).unwrap(), 0);
        assert_eq!(m.predict_class(&[5.1, 4.8], 9, &mut sel).unwrap(), 1);
    }

    #[test]
    fn exact_point_query() {
        let m = grid_model();
        let mut sel = HostSelector::default();
        // k=1 at an exact grid point returns that point's value
        let pred = m.predict_regression(&[1.0, 1.0], 1, &mut sel).unwrap();
        assert!((pred - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(KnnModel::new(vec![], vec![]).is_err());
        assert!(KnnModel::new(vec![vec![1.0]], vec![1.0, 2.0]).is_err());
        assert!(KnnModel::new(vec![vec![1.0], vec![1.0, 2.0]], vec![1.0, 2.0]).is_err());
        let m = grid_model();
        let mut sel = HostSelector::default();
        assert!(m.predict_regression(&[0.0, 0.0], 0, &mut sel).is_err());
        assert!(m.predict_regression(&[0.0, 0.0], 100000, &mut sel).is_err());
    }
}
