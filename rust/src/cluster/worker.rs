//! Worker-side serve loop: answer shard-level requests (`Shard*` in
//! [`crate::coordinator::messages`]) against a local [`DatasetBackend`],
//! with the same fault-isolation contract as the in-process worker loop —
//! a panicking or erroring backend fails exactly the request that hit it,
//! reported to the coordinator as a typed error frame, never the process.
//!
//! ## Cost-model shipping
//!
//! The worker accumulates [`PassCostModel`] sufficient statistics locally
//! (one observation per fused probe ladder, timed on the worker's own
//! clock — compute-only, no RTT) and ships them on
//! [`WireRequest::ShardStatsPull`], resetting its accumulator afterwards
//! so sums are merged into the coordinator's pool exactly once. The reply
//! carries the connection's registration version; the coordinator drops
//! bundles whose version is stale (see `crate::cluster::coordinator`).
//!
//! ## Reconnect semantics
//!
//! [`run_worker`] creates its backend **once** and keeps it across
//! reconnects: a worker that loses its coordinator keeps its uploaded
//! datasets, so after re-registration the next query on them succeeds
//! without a re-upload. A backend that *itself* reports
//! [`Error::Disconnected`] (a sharded device losing a peer) tears the
//! coordinator connection down without a reply — the coordinator must see
//! a transport failure, not a typed answer, so it fails only the in-flight
//! batch and waits for re-registration.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::time::Duration;

use crate::coordinator::dispatch::panic_msg;
use crate::coordinator::messages::{WireRequest, WireResponse};
use crate::coordinator::{BackendFactory, DatasetBackend};
use crate::select::PassCostModel;
use crate::testkit::Clock;
use crate::{Error, Result};

use super::transport::{TcpWire, Wire};

/// Why [`serve`] returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeExit {
    /// The wire (or the backend's own cluster link) died; the caller
    /// should reconnect and re-register.
    Disconnected,
    /// The coordinator asked the worker to exit.
    Shutdown,
}

/// Serve one registered connection until the coordinator shuts the worker
/// down or the wire dies. `version` is the registration version assigned
/// by the coordinator's `Registered` ack; it stamps every shipped
/// statistics bundle.
pub fn serve(
    wire: &mut dyn Wire,
    backend: &mut dyn DatasetBackend,
    stats: &mut PassCostModel,
    version: u64,
    clock: &Clock,
) -> ServeExit {
    loop {
        let frame = match wire.recv() {
            Ok(f) => f,
            Err(_) => return ServeExit::Disconnected,
        };
        let resp = match WireRequest::decode(&frame) {
            Err(e) => WireResponse::from_error(&e),
            Ok(WireRequest::Shutdown) => {
                let _ = wire.send(&WireResponse::Ok.encode());
                return ServeExit::Shutdown;
            }
            Ok(WireRequest::ShardStatsPull) => {
                // Ship-and-reset: these sums leave the worker exactly once.
                let shipped = WireResponse::ShardStats { model_json: stats.to_json(), version };
                *stats = PassCostModel::seeded();
                shipped
            }
            Ok(req) => {
                match catch_unwind(AssertUnwindSafe(|| {
                    handle_shard_op(backend, &req, stats, clock)
                })) {
                    Ok(Ok(r)) => r,
                    // The backend lost ITS peer: drop this connection with
                    // no reply so the coordinator sees a transport failure.
                    Ok(Err(Error::Disconnected { .. })) => return ServeExit::Disconnected,
                    Ok(Err(e)) => WireResponse::from_error(&e),
                    Err(p) => WireResponse::from_error(&Error::Service(format!(
                        "worker fault: {}",
                        panic_msg(p.as_ref())
                    ))),
                }
            }
        };
        if wire.send(&resp.encode()).is_err() {
            return ServeExit::Disconnected;
        }
    }
}

/// Execute one shard-level operation. The only call site is inside
/// [`serve`]'s `catch_unwind`, which is what lets a panicking backend fail
/// a single request instead of the worker process.
fn handle_shard_op(
    backend: &mut dyn DatasetBackend,
    req: &WireRequest,
    stats: &mut PassCostModel,
    clock: &Clock,
) -> Result<WireResponse> {
    match req {
        WireRequest::ShardUpload { dataset, data, dtype } => {
            backend.upload(*dataset, data, *dtype)?;
            let ev = backend.evaluator(*dataset)?;
            Ok(WireResponse::ShardUploaded {
                n: ev.n() as u64,
                dtype: ev.dtype(),
                ladder_width_hint: ev.ladder_width_hint().map(|h| h as u64),
                probes: ev.probes(),
            })
        }
        WireRequest::ShardInit { dataset } => {
            let ev = backend.evaluator(*dataset)?;
            let out = ev.init_stats()?;
            Ok(WireResponse::ShardInit { stats: out, probes: ev.probes() })
        }
        WireRequest::ShardProbe { dataset, ys } => {
            let t0_us = clock.now_us();
            let ev = backend.evaluator(*dataset)?;
            let n = ev.n();
            let before = ev.probes();
            let out = ev.probe_many(ys)?;
            let after = ev.probes();
            let wall = Duration::from_micros(clock.now_us().saturating_sub(t0_us));
            // One fused ladder pass, compute-only wall time. Under a frozen
            // virtual clock this observes zero wall, which the fit guards
            // discard (`coefficients` requires a positive sweep cost).
            stats.observe_run(1, ys.len() as u64, after.saturating_sub(before).max(1), n, wall);
            Ok(WireResponse::ShardProbes { stats: out, probes: after })
        }
        WireRequest::ShardNeighbors { dataset, y } => {
            let ev = backend.evaluator(*dataset)?;
            let out = ev.neighbors(*y)?;
            Ok(WireResponse::ShardNeighbors { stats: out, probes: ev.probes() })
        }
        WireRequest::ShardInterval { dataset, lo, hi } => {
            let ev = backend.evaluator(*dataset)?;
            let out = ev.interval(*lo, *hi)?;
            Ok(WireResponse::ShardInterval { counts: out, probes: ev.probes() })
        }
        WireRequest::ShardCompact { dataset, lo, hi } => {
            let ev = backend.evaluator(*dataset)?;
            let values = ev.compact(*lo, *hi)?;
            Ok(WireResponse::ShardValues { values, probes: ev.probes() })
        }
        WireRequest::ShardDownload { dataset } => {
            let ev = backend.evaluator(*dataset)?;
            let values = ev.download()?;
            Ok(WireResponse::ShardValues { values, probes: ev.probes() })
        }
        WireRequest::ShardLen { dataset } => {
            let n = backend
                .dataset_len(*dataset)
                .ok_or_else(|| Error::InvalidArg(format!("unknown dataset {dataset}")))?;
            Ok(WireResponse::ShardLen { n: n as u64 })
        }
        WireRequest::ShardDrop { dataset } => {
            backend.drop_dataset(*dataset);
            Ok(WireResponse::Ok)
        }
        _ => Err(Error::Service(
            "not a shard op: client requests go to the coordinator, not a worker".into(),
        )),
    }
}

/// Knobs for [`run_worker`].
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// TCP connect deadline for dialing the coordinator.
    pub connect_timeout: Duration,
    /// Pause between reconnect attempts after a lost connection.
    pub reconnect_backoff: Duration,
    /// Interval between heartbeat dials (zero disables the heartbeat).
    pub heartbeat: Duration,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            connect_timeout: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(200),
            heartbeat: Duration::from_secs(2),
        }
    }
}

/// Park this thread for `dur` without `thread::sleep`: wait on a channel
/// nobody writes to, via the clock so virtual-clock tests stay in control.
fn park(clock: &Clock, rx: &Receiver<()>, dur: Duration) {
    let deadline = clock.now_us().saturating_add(dur.as_micros() as u64);
    let _ = clock.recv_deadline(rx, deadline);
}

/// Run a worker process body: dial the coordinator, register, serve until
/// shutdown, reconnecting (with backoff) whenever the wire drops. The
/// backend is created once and survives reconnects, so uploaded datasets
/// outlive a coordinator hiccup.
pub fn run_worker(
    addr: &str,
    worker_id: u32,
    factory: BackendFactory,
    clock: Clock,
    opts: WorkerOptions,
) -> Result<()> {
    let mut backend = factory(worker_id as usize)?;
    let mut stats = PassCostModel::seeded();
    // Held-open parking channel (never written) for backoff waits.
    let (_park_tx, park_rx) = channel::<()>();
    // Heartbeat thread stops when this sender drops.
    let (hb_stop_tx, hb_stop_rx) = channel::<()>();
    let hb = if opts.heartbeat.is_zero() {
        None
    } else {
        let hb_addr = addr.to_string();
        let hb_clock = clock.clone();
        let hb_opts = opts.clone();
        Some(std::thread::spawn(move || {
            heartbeat_loop(&hb_addr, worker_id, &hb_clock, &hb_opts, &hb_stop_rx)
        }))
    };
    loop {
        // Serve connections block indefinitely waiting for work: no I/O
        // deadline (Duration::ZERO disables it).
        let mut wire = match TcpWire::connect(addr, opts.connect_timeout, Duration::ZERO) {
            Ok(w) => w,
            Err(_) => {
                park(&clock, &park_rx, opts.reconnect_backoff);
                continue;
            }
        };
        if wire.send(&WireRequest::Register { worker_id }.encode()).is_err() {
            park(&clock, &park_rx, opts.reconnect_backoff);
            continue;
        }
        let version = match wire.recv().and_then(|b| WireResponse::decode(&b)) {
            Ok(WireResponse::Registered { version, .. }) => version,
            _ => {
                park(&clock, &park_rx, opts.reconnect_backoff);
                continue;
            }
        };
        match serve(&mut wire, backend.as_mut(), &mut stats, version, &clock) {
            ServeExit::Shutdown => break,
            ServeExit::Disconnected => park(&clock, &park_rx, opts.reconnect_backoff),
        }
    }
    drop(hb_stop_tx);
    if let Some(h) = hb {
        let _ = h.join();
    }
    Ok(())
}

/// Heartbeat sidecar: dial the coordinator on its own short-lived
/// connections at a fixed cadence until the stop channel closes. Best
/// effort — a missed beat only ages `last_seen_us`.
fn heartbeat_loop(
    addr: &str,
    worker_id: u32,
    clock: &Clock,
    opts: &WorkerOptions,
    stop_rx: &Receiver<()>,
) {
    loop {
        let deadline = clock.now_us().saturating_add(opts.heartbeat.as_micros() as u64);
        match clock.recv_deadline(stop_rx, deadline) {
            Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
            Err(RecvTimeoutError::Timeout) => {}
        }
        if let Ok(mut wire) =
            TcpWire::connect(addr, opts.connect_timeout, opts.connect_timeout)
        {
            if wire.send(&WireRequest::Heartbeat { worker_id }.encode()).is_ok() {
                let _ = wire.recv();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::loopback_pair;
    use crate::coordinator::HostBackend;
    use crate::select::DType;

    fn exchange(coord: &mut dyn Wire, req: WireRequest) -> WireResponse {
        coord.send(&req.encode()).expect("send");
        WireResponse::decode(&coord.recv().expect("recv")).expect("decode")
    }

    /// Drive a serve loop over loopback from the "coordinator" side.
    fn with_serve<T>(body: impl FnOnce(&mut dyn Wire) -> T) -> (T, ServeExit) {
        let (mut coord_side, mut worker_side) = loopback_pair("worker-0", "coordinator");
        let server = std::thread::spawn(move || {
            let mut backend = HostBackend::default();
            let mut stats = PassCostModel::seeded();
            let (clock, _ctl) = Clock::manual();
            serve(&mut worker_side, &mut backend, &mut stats, 1, &clock)
        });
        let out = body(&mut coord_side);
        drop(coord_side);
        (out, server.join().expect("serve thread"))
    }

    #[test]
    fn upload_probe_and_shutdown_roundtrip() {
        let ((), exit) = with_serve(|coord| {
            let up = exchange(
                coord,
                WireRequest::ShardUpload {
                    dataset: 9,
                    data: vec![5.0, 1.0, 4.0, 2.0, 3.0],
                    dtype: DType::F64,
                },
            );
            match up {
                WireResponse::ShardUploaded { n, dtype, .. } => {
                    assert_eq!(n, 5);
                    assert_eq!(dtype, DType::F64);
                }
                other => panic!("unexpected upload reply: {other:?}"),
            }
            match exchange(coord, WireRequest::ShardProbe { dataset: 9, ys: vec![2.5, 3.5] }) {
                WireResponse::ShardProbes { stats, .. } => {
                    assert_eq!(stats.len(), 2);
                    assert_eq!(stats[0].c_lt, 2); // {1,2} < 2.5
                    assert_eq!(stats[1].c_lt, 3); // {1,2,3} < 3.5
                }
                other => panic!("unexpected probe reply: {other:?}"),
            }
            match exchange(coord, WireRequest::ShardLen { dataset: 9 }) {
                WireResponse::ShardLen { n } => assert_eq!(n, 5),
                other => panic!("unexpected len reply: {other:?}"),
            }
            assert_eq!(exchange(coord, WireRequest::Shutdown), WireResponse::Ok);
        });
        assert_eq!(exit, ServeExit::Shutdown);
    }

    #[test]
    fn bad_frames_and_bad_ops_get_error_replies_and_serving_continues() {
        let ((), exit) = with_serve(|coord| {
            coord.send(b"not json at all").expect("send garbage");
            let resp = WireResponse::decode(&coord.recv().expect("recv")).expect("decode");
            assert!(matches!(resp, WireResponse::Err { .. }), "{resp:?}");
            // unknown dataset: typed error, connection stays up
            let resp = exchange(coord, WireRequest::ShardInit { dataset: 404 });
            assert!(matches!(resp, WireResponse::Err { .. }), "{resp:?}");
            // a client-side op on a worker is a protocol error
            let resp = exchange(coord, WireRequest::Stats);
            assert!(matches!(resp, WireResponse::Err { .. }), "{resp:?}");
            assert_eq!(exchange(coord, WireRequest::Shutdown), WireResponse::Ok);
        });
        assert_eq!(exit, ServeExit::Shutdown);
    }

    #[test]
    fn coordinator_vanishing_ends_serve_with_disconnected() {
        let ((), exit) = with_serve(|_coord| ());
        assert_eq!(exit, ServeExit::Disconnected);
    }

    #[test]
    fn stats_pull_ships_and_resets() {
        let ((), exit) = with_serve(|coord| {
            let _ = exchange(
                coord,
                WireRequest::ShardUpload {
                    dataset: 1,
                    data: (0..64).map(|i| i as f64).collect(),
                    dtype: DType::F64,
                },
            );
            let _ = exchange(coord, WireRequest::ShardProbe { dataset: 1, ys: vec![31.5] });
            match exchange(coord, WireRequest::ShardStatsPull) {
                WireResponse::ShardStats { model_json, version } => {
                    assert_eq!(version, 1);
                    let shipped = PassCostModel::from_json(&model_json).expect("parse");
                    assert_eq!(shipped.samples(), 1);
                }
                other => panic!("unexpected stats reply: {other:?}"),
            }
            // after the reset a second pull ships an empty accumulator
            match exchange(coord, WireRequest::ShardStatsPull) {
                WireResponse::ShardStats { model_json, .. } => {
                    let shipped = PassCostModel::from_json(&model_json).expect("parse");
                    assert_eq!(shipped.samples(), 0, "ship-and-reset must not double-count");
                }
                other => panic!("unexpected stats reply: {other:?}"),
            }
            assert_eq!(exchange(coord, WireRequest::Shutdown), WireResponse::Ok);
        });
        assert_eq!(exit, ServeExit::Shutdown);
    }
}
