//! Wire transports for cluster mode: a byte-frame pipe abstraction
//! ([`Wire`]) with two implementations — an in-process loopback pair for
//! deterministic tests, and TCP with connect/read/write deadlines for real
//! deployments. Framing and payload encoding live in
//! [`crate::coordinator::messages`]; a transport only moves frames and
//! classifies its failures.
//!
//! ## Failure taxonomy
//!
//! Peer-gone conditions (EOF, connection reset, broken pipe, a dropped
//! loopback channel) become [`Error::Disconnected`] with the peer's name
//! attached — the typed contract callers use to fail exactly the in-flight
//! batch and then re-acquire a fresh connection. I/O *timeouts* become
//! [`Error::Service`]: the peer may still be alive, the caller just gave
//! up waiting. Everything else stays [`Error::Io`] with context.

use std::io::ErrorKind;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Duration;

use crate::coordinator::messages::{read_frame, write_frame};
use crate::{Error, Result};

/// One side of a bidirectional frame pipe. Implementations move whole
/// frames (length-prefixed on TCP, whole `Vec<u8>` messages on loopback)
/// and classify transport failures per the module docs.
pub trait Wire: Send {
    /// Send one frame payload.
    fn send(&mut self, payload: &[u8]) -> Result<()>;
    /// Receive one frame payload; blocks until a frame, a timeout, or a
    /// peer-gone condition.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Human-readable name of the other end (error messages and logs).
    fn peer(&self) -> String;
    /// Adjust the per-op I/O deadline where the transport supports one
    /// (`Duration::ZERO` disables it). Deadline-free transports ignore it.
    fn set_io_timeout(&mut self, _t: Duration) {}
}

// ---------------------------------------------------------------------------
// loopback

/// In-process [`Wire`] backed by a pair of channels. Dropping either side
/// closes both directions, which is how tests simulate a peer vanishing
/// mid-conversation: the survivor's next `send`/`recv` reports
/// [`Error::Disconnected`], exactly like a TCP reset.
pub struct LoopbackWire {
    tx: SyncSender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    peer: String,
}

/// Build a connected loopback pair; `a_peer`/`b_peer` name what each side
/// talks *to* (side A reports `a_peer` in its errors).
pub fn loopback_pair(a_peer: &str, b_peer: &str) -> (LoopbackWire, LoopbackWire) {
    // Request/response protocols keep at most one frame in flight per
    // direction; the slack only decouples shutdown ordering.
    let (a_tx, b_rx) = sync_channel(16);
    let (b_tx, a_rx) = sync_channel(16);
    (
        LoopbackWire { tx: a_tx, rx: a_rx, peer: a_peer.to_string() },
        LoopbackWire { tx: b_tx, rx: b_rx, peer: b_peer.to_string() },
    )
}

impl Wire for LoopbackWire {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        self.tx
            .send(payload.to_vec())
            .map_err(|_| Error::Disconnected { peer: self.peer.clone() })
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| Error::Disconnected { peer: self.peer.clone() })
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

// ---------------------------------------------------------------------------
// TCP

/// TCP-backed [`Wire`]: length-prefixed frames over one stream, with
/// connect/read/write deadlines so a hung peer cannot wedge a worker
/// thread forever.
pub struct TcpWire {
    stream: TcpStream,
    peer: String,
}

impl TcpWire {
    /// Dial `addr` with a connect deadline, then apply `io_timeout` to
    /// every read and write (`Duration::ZERO` disables the I/O deadline —
    /// used by serve loops that legitimately block waiting for work).
    pub fn connect(addr: &str, connect_timeout: Duration, io_timeout: Duration) -> Result<TcpWire> {
        let sa = addr
            .to_socket_addrs()
            .map_err(|e| Error::io(addr, e))?
            .next()
            .ok_or_else(|| Error::Parse(format!("address {addr:?} resolves to nothing")))?;
        let stream = if connect_timeout.is_zero() {
            TcpStream::connect(sa).map_err(|e| Error::io(addr, e))?
        } else {
            TcpStream::connect_timeout(&sa, connect_timeout).map_err(|e| Error::io(addr, e))?
        };
        Self::from_stream(stream, io_timeout)
    }

    /// Wrap an accepted stream (coordinator side).
    pub fn from_stream(stream: TcpStream, io_timeout: Duration) -> Result<TcpWire> {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp-peer".to_string());
        stream.set_nodelay(true).map_err(|e| Error::io(&*peer, e))?;
        let t = if io_timeout.is_zero() { None } else { Some(io_timeout) };
        stream.set_read_timeout(t).map_err(|e| Error::io(&*peer, e))?;
        stream.set_write_timeout(t).map_err(|e| Error::io(&*peer, e))?;
        Ok(TcpWire { stream, peer })
    }

    fn classify(peer: &str, e: std::io::Error) -> Error {
        match e.kind() {
            ErrorKind::UnexpectedEof
            | ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::BrokenPipe
            | ErrorKind::NotConnected => Error::Disconnected { peer: peer.to_string() },
            // read/write deadline expiry surfaces as WouldBlock on Unix
            // and TimedOut elsewhere; either way the peer may be alive
            ErrorKind::TimedOut | ErrorKind::WouldBlock => {
                Error::Service(format!("wire timeout talking to {peer}: {e}"))
            }
            _ => Error::io(peer.to_string(), e),
        }
    }
}

impl Wire for TcpWire {
    fn send(&mut self, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, payload).map_err(|e| Self::classify(&self.peer, e))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        read_frame(&mut self.stream).map_err(|e| Self::classify(&self.peer, e))
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn set_io_timeout(&mut self, t: Duration) {
        let t = if t.is_zero() { None } else { Some(t) };
        let _ = self.stream.set_read_timeout(t);
        let _ = self.stream.set_write_timeout(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_frames_roundtrip_both_ways() {
        let (mut a, mut b) = loopback_pair("side-b", "side-a");
        a.send(b"ping").expect("a sends");
        assert_eq!(b.recv().expect("b receives"), b"ping");
        b.send(b"pong").expect("b sends");
        assert_eq!(a.recv().expect("a receives"), b"pong");
        assert_eq!(a.peer(), "side-b");
        assert_eq!(b.peer(), "side-a");
    }

    #[test]
    fn dropping_one_side_disconnects_the_other() {
        let (mut a, b) = loopback_pair("side-b", "side-a");
        drop(b);
        let e = a.send(b"into the void").expect_err("send must fail");
        assert!(matches!(e, Error::Disconnected { ref peer } if peer == "side-b"), "{e:?}");
        let e = a.recv().expect_err("recv must fail");
        assert!(matches!(e, Error::Disconnected { .. }), "{e:?}");
    }

    #[test]
    fn tcp_wire_roundtrips_and_reports_eof_as_disconnected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().expect("accept");
            let mut srv =
                TcpWire::from_stream(stream, Duration::from_secs(5)).expect("server wire");
            let got = srv.recv().expect("server receives");
            srv.send(&got).expect("server echoes");
            // server exits: stream closes, client sees EOF
        });
        let mut cli = TcpWire::connect(&addr, Duration::from_secs(5), Duration::from_secs(5))
            .expect("client connects");
        cli.send(b"echo me").expect("client sends");
        assert_eq!(cli.recv().expect("client receives"), b"echo me");
        t.join().expect("server thread");
        let e = cli.recv().expect_err("EOF after server exit");
        assert!(matches!(e, Error::Disconnected { .. }), "{e:?}");
    }

    #[test]
    fn tcp_read_deadline_is_a_service_error_not_a_disconnect() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        // server accepts and then says nothing
        let t = std::thread::spawn(move || listener.accept().expect("accept"));
        let mut cli = TcpWire::connect(&addr, Duration::from_secs(5), Duration::from_millis(30))
            .expect("client connects");
        let (_held, _) = t.join().expect("server thread");
        let e = cli.recv().expect_err("silent peer must time out");
        assert!(matches!(e, Error::Service(_)), "timeout must stay retryable: {e:?}");
    }
}
