//! Coordinator-side cluster plumbing: the worker [`Registry`] (who is
//! connected, at what registration version), per-worker [`ConnState`]
//! (request/response calls with reconnect-on-transport-failure), and the
//! [`RemoteBackend`]/[`RemoteEvaluator`] pair that makes a TCP worker look
//! like any other [`DatasetBackend`].
//!
//! Because a remote worker plugs into the unchanged
//! [`SelectionService`](crate::coordinator::SelectionService) through the
//! ordinary [`BackendFactory`], the wire path inherits admission control,
//! deadlines, micro-batch planning, and the [`CostModelPool`] by
//! construction — there is no second dispatch path to keep in sync.
//!
//! ## Registration versions and stale statistics
//!
//! Every (re)registration of a worker id bumps a monotonically increasing
//! *version*. The version travels with the connection and with every
//! shipped statistics bundle; the coordinator merges worker-side cost-model
//! sums into the shared pool only when the bundle's version matches both
//! the connection it arrived on *and* the registry's current version for
//! that worker. A worker that crashed and re-registered mid-pull therefore
//! cannot smuggle pre-crash sums into the pool.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Condvar};
use std::time::Duration;

use crate::coordinator::messages::{WireRequest, WireResponse};
use crate::coordinator::service::DatasetId;
use crate::coordinator::{BackendFactory, DatasetBackend};
use crate::select::gpu_model::{CostModelPool, PassCostModel};
use crate::select::{DType, Evaluator, InitStats, IntervalCounts, Neighbors, ProbeStats};
use crate::util::sync::{OrderedMutex, RANK_CLUSTER_REGISTRY};
use crate::{Error, Result};

use super::transport::Wire;

/// One registered worker: its parked connection (taken while a call is in
/// flight), registration version, and last observed heartbeat.
struct WorkerSlot {
    conn: Option<Box<dyn Wire>>,
    version: u64,
    last_seen_us: u64,
}

/// Tracks which workers are connected. Connections are *checked out* for
/// the duration of a call ([`take_conn`](Registry::take_conn) /
/// [`put_conn`](Registry::put_conn)) so the rank-25 lock is never held
/// across wire I/O.
pub struct Registry {
    slots: OrderedMutex<HashMap<u32, WorkerSlot>>,
    cv: Condvar,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            slots: OrderedMutex::new(RANK_CLUSTER_REGISTRY, "cluster.registry", HashMap::new()),
            cv: Condvar::new(),
        }
    }
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Register (or re-register) `worker_id`, acknowledging over `wire`
    /// before the connection becomes available for checkout. Two-phase on
    /// purpose: the version is bumped and read under the lock, the
    /// `Registered` ack is sent with the lock *released*, and the
    /// connection is installed only if no newer registration raced in
    /// between (newest registration wins). Returns the assigned version.
    pub fn register(
        &self,
        worker_id: u32,
        mut wire: Box<dyn Wire>,
        now_us: u64,
    ) -> Result<u64> {
        let version = {
            let mut slots = self.slots.lock();
            let slot = slots.entry(worker_id).or_insert(WorkerSlot {
                conn: None,
                version: 0,
                last_seen_us: now_us,
            });
            slot.version += 1;
            slot.last_seen_us = now_us;
            // A re-registration replaces any parked connection: the old
            // one is dead or about to be.
            slot.conn = None;
            slot.version
        };
        wire.send(&WireResponse::Registered { worker_id, version }.encode())?;
        let mut slots = self.slots.lock();
        if let Some(slot) = slots.get_mut(&worker_id) {
            if slot.version == version {
                slot.conn = Some(wire);
                self.cv.notify_all();
            }
        }
        Ok(version)
    }

    /// Check out `worker_id`'s connection, waiting up to `timeout` for the
    /// worker to (re)register if it is currently absent.
    pub fn take_conn(&self, worker_id: u32, timeout: Duration) -> Result<(Box<dyn Wire>, u64)> {
        let mut slots = self.slots.lock();
        loop {
            if let Some(slot) = slots.get_mut(&worker_id) {
                if let Some(conn) = slot.conn.take() {
                    return Ok((conn, slot.version));
                }
            }
            let (again, timed_out) = slots.wait_timeout(&self.cv, timeout);
            slots = again;
            if timed_out {
                if let Some(slot) = slots.get_mut(&worker_id) {
                    if let Some(conn) = slot.conn.take() {
                        return Ok((conn, slot.version));
                    }
                }
                return Err(Error::Disconnected {
                    peer: format!("worker-{worker_id} (not registered)"),
                });
            }
        }
    }

    /// Return a checked-out connection. Dropped silently if the worker
    /// re-registered in the meantime (`version` stale) or a fresh
    /// connection is already parked.
    pub fn put_conn(&self, worker_id: u32, wire: Box<dyn Wire>, version: u64) {
        let mut slots = self.slots.lock();
        if let Some(slot) = slots.get_mut(&worker_id) {
            if slot.version == version && slot.conn.is_none() {
                slot.conn = Some(wire);
                self.cv.notify_all();
            }
        }
    }

    /// Record a heartbeat for `worker_id` (no-op for unknown workers).
    pub fn heartbeat(&self, worker_id: u32, now_us: u64) {
        let mut slots = self.slots.lock();
        if let Some(slot) = slots.get_mut(&worker_id) {
            slot.last_seen_us = now_us;
        }
    }

    /// Current registration version for `worker_id` (0 = never seen).
    pub fn current_version(&self, worker_id: u32) -> u64 {
        self.slots.lock().get(&worker_id).map(|s| s.version).unwrap_or(0)
    }

    /// Microseconds of the last heartbeat/registration (None = never seen).
    pub fn last_seen_us(&self, worker_id: u32) -> Option<u64> {
        self.slots.lock().get(&worker_id).map(|s| s.last_seen_us)
    }

    /// Take every parked connection (shutdown propagation).
    pub fn drain_conns(&self) -> Vec<Box<dyn Wire>> {
        let mut slots = self.slots.lock();
        slots.values_mut().filter_map(|s| s.conn.take()).collect()
    }
}

/// One coordinator worker thread's view of its remote peer. Each call
/// checks the connection out of the [`Registry`], runs one exchange, and
/// parks it again — so an *idle* cluster always has every worker
/// connection in the registry, where shutdown propagation and
/// re-registration can reach it.
pub struct ConnState {
    registry: Arc<Registry>,
    worker_id: u32,
    acquire_timeout: Duration,
}

impl ConnState {
    pub fn new(registry: Arc<Registry>, worker_id: u32, acquire_timeout: Duration) -> ConnState {
        ConnState { registry, worker_id, acquire_timeout }
    }

    pub fn worker_id(&self) -> u32 {
        self.worker_id
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// One request/response exchange; see [`ConnState::call_versioned`].
    pub fn call(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.call_versioned(req, self.acquire_timeout).map(|(resp, _)| resp)
    }

    /// One request/response exchange, also reporting the registration
    /// version of the connection that carried it (the stale-statistics
    /// fence needs it). A *protocol* error (the worker answered with
    /// [`WireResponse::Err`]) parks the connection again — the stream is
    /// still framed correctly. A *transport* error (send, recv, or an
    /// undecodable frame) drops it, so the next call waits for the
    /// worker's reconnect instead of reusing a broken stream.
    pub fn call_versioned(
        &mut self,
        req: &WireRequest,
        acquire_timeout: Duration,
    ) -> Result<(WireResponse, u64)> {
        let (mut wire, version) = self.registry.take_conn(self.worker_id, acquire_timeout)?;
        let exchange = (|| -> Result<WireResponse> {
            wire.send(&req.encode())?;
            WireResponse::decode(&wire.recv()?)
        })();
        match exchange {
            Ok(resp) => {
                self.registry.put_conn(self.worker_id, wire, version);
                if matches!(resp, WireResponse::Err { .. }) {
                    return Err(resp.into_error().unwrap_or_else(|| {
                        Error::Service("worker sent an unintelligible error".into())
                    }));
                }
                Ok((resp, version))
            }
            Err(e) => Err(e),
        }
    }
}

fn unexpected(op: &str) -> Error {
    Error::Service(format!("unexpected reply to {op}"))
}

/// Coordinator-side proxy for one dataset living on a remote worker. Every
/// probe ladder the cutting-plane solver issues becomes one
/// [`WireRequest::ShardProbe`] round trip — the fused-pass batching the
/// paper's Algorithm 1 relies on survives the wire unchanged.
pub struct RemoteEvaluator {
    conn: Rc<RefCell<ConnState>>,
    dataset: DatasetId,
    n: usize,
    dtype: DType,
    hint: Option<usize>,
    probes: u64,
}

impl Evaluator for RemoteEvaluator {
    fn n(&self) -> usize {
        self.n
    }

    fn dtype(&self) -> DType {
        self.dtype
    }

    fn init_stats(&mut self) -> Result<InitStats> {
        let req = WireRequest::ShardInit { dataset: self.dataset };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardInit { stats, probes } => {
                self.probes = probes;
                Ok(stats)
            }
            _ => Err(unexpected("shard_init")),
        }
    }

    fn probe(&mut self, y: f64) -> Result<ProbeStats> {
        let mut stats = self.probe_many(std::slice::from_ref(&y))?;
        stats.pop().ok_or_else(|| unexpected("shard_probe"))
    }

    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        let req = WireRequest::ShardProbe { dataset: self.dataset, ys: ys.to_vec() };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardProbes { stats, probes } => {
                if stats.len() != ys.len() {
                    return Err(Error::Service(format!(
                        "shard_probe answered {} stats for {} probes",
                        stats.len(),
                        ys.len()
                    )));
                }
                self.probes = probes;
                Ok(stats)
            }
            _ => Err(unexpected("shard_probe")),
        }
    }

    fn neighbors(&mut self, y: f64) -> Result<Neighbors> {
        let req = WireRequest::ShardNeighbors { dataset: self.dataset, y };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardNeighbors { stats, probes } => {
                self.probes = probes;
                Ok(stats)
            }
            _ => Err(unexpected("shard_neighbors")),
        }
    }

    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts> {
        let req = WireRequest::ShardInterval { dataset: self.dataset, lo, hi };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardInterval { counts, probes } => {
                self.probes = probes;
                Ok(counts)
            }
            _ => Err(unexpected("shard_interval")),
        }
    }

    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>> {
        let req = WireRequest::ShardCompact { dataset: self.dataset, lo, hi };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardValues { values, probes } => {
                self.probes = probes;
                Ok(values)
            }
            _ => Err(unexpected("shard_compact")),
        }
    }

    fn download(&mut self) -> Result<Vec<f64>> {
        let req = WireRequest::ShardDownload { dataset: self.dataset };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardValues { values, probes } => {
                self.probes = probes;
                Ok(values)
            }
            _ => Err(unexpected("shard_download")),
        }
    }

    fn probes(&self) -> u64 {
        self.probes
    }

    fn ladder_width_hint(&self) -> Option<usize> {
        self.hint
    }
}

/// [`DatasetBackend`] whose datasets live on one remote worker. Thread
/// confined like every backend; the shared [`ConnState`] lets the backend
/// and its evaluators reuse one checked-out connection.
pub struct RemoteBackend {
    conn: Rc<RefCell<ConnState>>,
    pool: Arc<CostModelPool>,
    datasets: HashMap<u64, RemoteEvaluator>,
}

impl RemoteBackend {
    pub fn new(
        registry: Arc<Registry>,
        pool: Arc<CostModelPool>,
        worker_id: u32,
        acquire_timeout: Duration,
    ) -> RemoteBackend {
        RemoteBackend {
            conn: Rc::new(RefCell::new(ConnState::new(registry, worker_id, acquire_timeout))),
            pool,
            datasets: HashMap::new(),
        }
    }

    /// [`BackendFactory`] mapping coordinator worker-thread index `i` to
    /// remote worker id `i % workers`. Run the service with as many worker
    /// threads as remote workers for a 1:1 pinning (the cluster CLI does).
    pub fn factory(
        registry: Arc<Registry>,
        pool: Arc<CostModelPool>,
        workers: u32,
        acquire_timeout: Duration,
    ) -> BackendFactory {
        let workers = workers.max(1);
        Arc::new(move |worker_idx| {
            let id = (worker_idx as u32) % workers;
            Ok(Box::new(RemoteBackend::new(
                Arc::clone(&registry),
                Arc::clone(&pool),
                id,
                acquire_timeout,
            )) as Box<dyn DatasetBackend>)
        })
    }

    /// Pull the worker's cost-model sufficient statistics and merge them
    /// into the shared pool, with the double version fence described in
    /// the module docs. Best-effort: transport trouble here must never
    /// fail a batch, so errors are swallowed, and the registry acquire
    /// uses a near-zero timeout — a batch boundary never waits for an
    /// absent worker.
    fn pull_stats(&mut self) {
        let mut conn = self.conn.borrow_mut();
        let worker_id = conn.worker_id();
        let registry = Arc::clone(conn.registry());
        if let Ok((WireResponse::ShardStats { model_json, version }, conn_version)) =
            conn.call_versioned(&WireRequest::ShardStatsPull, Duration::from_millis(5))
        {
            if version == conn_version && registry.current_version(worker_id) == version {
                if let Ok(model) = PassCostModel::from_json(&model_json) {
                    self.pool.merge(&model);
                }
            }
        }
    }
}

impl DatasetBackend for RemoteBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        let req = WireRequest::ShardUpload { dataset: id, data: data.to_vec(), dtype };
        match self.conn.borrow_mut().call(&req)? {
            WireResponse::ShardUploaded { n, dtype, ladder_width_hint, probes } => {
                self.datasets.insert(
                    id,
                    RemoteEvaluator {
                        conn: Rc::clone(&self.conn),
                        dataset: id,
                        n: n as usize,
                        dtype,
                        hint: ladder_width_hint.map(|h| h as usize),
                        probes,
                    },
                );
                Ok(())
            }
            _ => Err(unexpected("shard_upload")),
        }
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        self.datasets
            .get_mut(&id)
            .map(|ev| ev as &mut dyn Evaluator)
            .ok_or_else(|| Error::InvalidArg(format!("unknown dataset {id}")))
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        let known = self.datasets.remove(&id).is_some();
        if known {
            // Best-effort: the worker garbage-collects on reconnect anyway.
            let _ = self.conn.borrow_mut().call(&WireRequest::ShardDrop { dataset: id });
        }
        known
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        self.datasets.get(&id).map(|ev| ev.n)
    }

    fn kind(&self) -> &'static str {
        "remote"
    }

    fn take_evictions(&mut self) -> u64 {
        // Batch boundary: opportunistically fold the worker's cost-model
        // sums into the shared pool. Remote workers never self-evict.
        self.pull_stats();
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::loopback_pair;

    #[test]
    fn registry_register_take_put_roundtrip() {
        let reg = Registry::new();
        let (coord_side, _worker_side) = loopback_pair("worker-7", "coordinator");
        let v = reg.register(7, Box::new(coord_side), 10).expect("register");
        assert_eq!(v, 1);
        assert_eq!(reg.current_version(7), 1);
        let (conn, version) = reg.take_conn(7, Duration::from_millis(50)).expect("take");
        assert_eq!(version, 1);
        reg.put_conn(7, conn, version);
        let again = reg.take_conn(7, Duration::from_millis(50));
        assert!(again.is_ok(), "reinstalled conn must be takeable");
    }

    #[test]
    fn take_conn_times_out_as_disconnected_for_unknown_worker() {
        let reg = Registry::new();
        let e = reg.take_conn(3, Duration::from_millis(10)).expect_err("no worker 3");
        assert_eq!(e.kind(), crate::error::ErrorKind::Disconnected);
        assert!(e.to_string().contains("worker-3"), "{e}");
    }

    #[test]
    fn reregistration_bumps_version_and_invalidates_stale_put() {
        let reg = Registry::new();
        let (a, _ka) = loopback_pair("worker-1", "coordinator");
        let v1 = reg.register(1, Box::new(a), 0).expect("first registration");
        let (old_conn, old_version) = reg.take_conn(1, Duration::from_millis(50)).expect("take");
        let (b, _kb) = loopback_pair("worker-1", "coordinator");
        let v2 = reg.register(1, Box::new(b), 5).expect("second registration");
        assert!(v2 > v1);
        // Returning the pre-restart connection must be a no-op...
        reg.put_conn(1, old_conn, old_version);
        // ...so the parked connection is the *new* one, at the new version.
        let (_conn, version) = reg.take_conn(1, Duration::from_millis(50)).expect("take new");
        assert_eq!(version, v2);
    }

    #[test]
    fn registration_ack_carries_id_and_version() {
        let reg = Registry::new();
        let (coord_side, mut worker_side) = loopback_pair("worker-2", "coordinator");
        reg.register(2, Box::new(coord_side), 0).expect("register");
        let ack = WireResponse::decode(&worker_side.recv().expect("ack frame")).expect("decode");
        assert_eq!(ack, WireResponse::Registered { worker_id: 2, version: 1 });
    }

    #[test]
    fn heartbeat_updates_last_seen_for_known_workers_only() {
        let reg = Registry::new();
        let (coord_side, _keep) = loopback_pair("worker-4", "coordinator");
        reg.register(4, Box::new(coord_side), 100).expect("register");
        reg.heartbeat(4, 250);
        assert_eq!(reg.last_seen_us(4), Some(250));
        reg.heartbeat(99, 300);
        assert_eq!(reg.last_seen_us(99), None);
    }
}
