//! Out-of-process cluster mode: a TCP coordinator front-end over the
//! unchanged [`SelectionService`], plus the worker process body and a
//! typed client.
//!
//! ## Shape
//!
//! ```text
//!  clients ── TCP ──▶ coordinator (accept loop)
//!                        │  SelectionService (admission, deadlines,
//!                        │  batching/coalescing, CostModelPool)
//!                        │     └─ RemoteBackend per worker thread
//!                        └── TCP ──▶ worker processes (serve loop over a
//!                                    local DatasetBackend)
//! ```
//!
//! The coordinator embeds the ordinary [`SelectionService`]; its worker
//! threads talk to remote workers through
//! [`RemoteBackend`](coordinator::RemoteBackend), a
//! [`DatasetBackend`](crate::coordinator::DatasetBackend) whose probes
//! travel over TCP. That is the whole trick: because the wire path enters
//! through the same [`BackendFactory`](crate::coordinator::BackendFactory)
//! as an in-process backend, admission control, deadline enforcement,
//! micro-batch
//! planning, query coalescing, and cost-model pooling apply to cluster
//! traffic *by construction* — there is no second dispatch path.
//!
//! Connection roles are decided by the first frame a peer sends:
//! [`WireRequest::Register`] parks the connection in the worker
//! [`Registry`](coordinator::Registry), [`WireRequest::Heartbeat`] is a
//! one-shot liveness ping, and anything else starts a client session
//! served until the peer hangs up (or sends
//! [`WireRequest::Shutdown`], which stops the whole coordinator and
//! propagates shutdown to every parked worker).

pub mod coordinator;
pub mod transport;
pub mod worker;

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::messages::{WireRequest, WireResponse};
use crate::coordinator::service::{DatasetId, KSpec, QueryOptions, QueryResult};
use crate::coordinator::SelectionService;
use crate::select::{DType, Method};
use crate::testkit::Clock;
use crate::{Error, Result};

use coordinator::Registry;
use transport::{TcpWire, Wire};

pub use coordinator::{ConnState, RemoteBackend, RemoteEvaluator};
pub use worker::{run_worker, serve, ServeExit, WorkerOptions};

/// Knobs for [`run_coordinator`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Read deadline on client connections. Idle clients are *not*
    /// disconnected — a timed-out read just re-checks the stop flag — so
    /// this bounds how long shutdown convergence can take.
    pub client_poll: Duration,
    /// Read/write deadline for coordinator→worker shard calls: a hung
    /// worker fails the in-flight batch instead of wedging a coordinator
    /// worker thread forever.
    pub shard_io_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            client_poll: Duration::from_secs(1),
            shard_io_timeout: Duration::from_secs(30),
        }
    }
}

/// Serve a coordinator on `listener` until a client sends
/// [`WireRequest::Shutdown`]. Owns the service: on shutdown it joins the
/// client sessions, shuts the service down (which persists the cost-model
/// pool's sidecar), and then propagates [`WireRequest::Shutdown`] to
/// every parked worker connection so worker processes exit too.
pub fn run_coordinator(
    listener: TcpListener,
    svc: SelectionService,
    registry: Arc<Registry>,
    clock: Clock,
    opts: ServeOptions,
) -> Result<()> {
    let svc = Arc::new(svc);
    let stop = Arc::new(AtomicBool::new(false));
    let local = listener.local_addr().map_err(|e| Error::io("cluster-listener", e))?;
    let mut sessions: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let svc = Arc::clone(&svc);
        let registry = Arc::clone(&registry);
        let stop = Arc::clone(&stop);
        let clock = clock.clone();
        let conn_opts = opts.clone();
        sessions.push(std::thread::spawn(move || {
            handle_connection(stream, svc, registry, stop, clock, conn_opts, local);
        }));
        sessions.retain(|h| !h.is_finished());
    }
    drop(listener);
    for h in sessions {
        let _ = h.join();
    }
    // All sessions joined: this is the last Arc. Shutting the service
    // down joins its worker threads, which parks every live worker
    // connection back in the registry — where shutdown can reach it.
    match Arc::try_unwrap(svc) {
        Ok(svc) => svc.shutdown(),
        Err(_) => return Err(Error::Service("cluster session leaked the service".into())),
    }
    for mut conn in registry.drain_conns() {
        if conn.send(&WireRequest::Shutdown.encode()).is_ok() {
            let _ = conn.recv();
        }
    }
    Ok(())
}

/// First-frame routing: workers register, heartbeats ack and close,
/// everything else becomes a client session.
fn handle_connection(
    stream: TcpStream,
    svc: Arc<SelectionService>,
    registry: Arc<Registry>,
    stop: Arc<AtomicBool>,
    clock: Clock,
    opts: ServeOptions,
    local: std::net::SocketAddr,
) {
    let Ok(mut wire) = TcpWire::from_stream(stream, opts.client_poll) else { return };
    let first = loop {
        match wire.recv() {
            Ok(frame) => break frame,
            // poll timeout: an idle peer that has not identified itself yet
            Err(Error::Service(_)) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    };
    match WireRequest::decode(&first) {
        Ok(WireRequest::Register { worker_id }) => {
            // Worker calls block for up to a shard's compute time, not a
            // client poll tick.
            wire.set_io_timeout(opts.shard_io_timeout);
            let _ = registry.register(worker_id, Box::new(wire), clock.now_us());
        }
        Ok(WireRequest::Heartbeat { worker_id }) => {
            registry.heartbeat(worker_id, clock.now_us());
            let _ = wire.send(&WireResponse::Ok.encode());
        }
        first_req => {
            let mut pending = Some(first_req);
            loop {
                let req = match pending.take() {
                    Some(r) => r,
                    None => match wire.recv() {
                        Ok(frame) => WireRequest::decode(&frame),
                        Err(Error::Service(_)) => {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            continue;
                        }
                        Err(_) => return,
                    },
                };
                let (resp, shutdown) = match req {
                    Err(e) => (WireResponse::from_error(&e), false),
                    Ok(req) => answer_client(&svc, req),
                };
                if wire.send(&resp.encode()).is_err() {
                    return;
                }
                if shutdown {
                    stop.store(true, Ordering::SeqCst);
                    // Wake the blocking accept so the loop observes stop.
                    let _ = TcpStream::connect(local);
                    return;
                }
            }
        }
    }
}

/// Execute one client op against the embedded service. Returns the reply
/// and whether it was a shutdown request.
fn answer_client(svc: &SelectionService, req: WireRequest) -> (WireResponse, bool) {
    let resp = match req {
        WireRequest::Upload { data, dtype } => svc
            .upload(data, dtype)
            .map(|dataset| WireResponse::Uploaded { dataset }),
        WireRequest::Query { dataset, spec, method, tenant, deadline_rel_us } => svc
            .query_opts(
                dataset,
                spec,
                QueryOptions {
                    method,
                    tenant,
                    deadline: deadline_rel_us.map(Duration::from_micros),
                },
            )
            .map(|result| WireResponse::Result { result }),
        WireRequest::QueryMany { dataset, specs, method, tenant, deadline_rel_us } => svc
            .query_many_opts(
                dataset,
                specs,
                QueryOptions {
                    method,
                    tenant,
                    deadline: deadline_rel_us.map(Duration::from_micros),
                },
            )
            .map(|results| WireResponse::Results { results }),
        WireRequest::Drop { dataset } => {
            svc.drop_dataset_sync(dataset).map(|()| WireResponse::Ok)
        }
        WireRequest::Stats => Ok(WireResponse::StatsText {
            text: svc.metrics.snapshot().to_string(),
        }),
        WireRequest::Shutdown => return (WireResponse::Ok, true),
        WireRequest::Register { .. } | WireRequest::Heartbeat { .. } => Err(Error::Service(
            "register/heartbeat must be a connection's first frame".into(),
        )),
        _ => Err(Error::Service(
            "shard ops go to workers, not the coordinator".into(),
        )),
    };
    (resp.unwrap_or_else(|e| WireResponse::from_error(&e)), false)
}

/// Typed client for a cluster coordinator: one request/response exchange
/// per call over a single connection. Protocol errors come back as the
/// same typed [`Error`] values the in-process service returns — including
/// the µs payloads of `Overloaded`/`DeadlineExceeded`.
pub struct ClusterClient {
    wire: Box<dyn Wire>,
}

impl ClusterClient {
    /// Dial a coordinator.
    pub fn connect(addr: &str, connect_timeout: Duration, io_timeout: Duration) -> Result<Self> {
        Ok(ClusterClient { wire: Box::new(TcpWire::connect(addr, connect_timeout, io_timeout)?) })
    }

    /// Wrap an existing wire (loopback tests).
    pub fn from_wire(wire: Box<dyn Wire>) -> Self {
        ClusterClient { wire }
    }

    fn call(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.wire.send(&req.encode())?;
        let resp = WireResponse::decode(&self.wire.recv()?)?;
        if matches!(resp, WireResponse::Err { .. }) {
            return Err(resp.into_error().unwrap_or_else(|| {
                Error::Service("coordinator sent an unintelligible error".into())
            }));
        }
        Ok(resp)
    }

    pub fn upload(&mut self, data: Vec<f64>, dtype: DType) -> Result<DatasetId> {
        match self.call(&WireRequest::Upload { data, dtype })? {
            WireResponse::Uploaded { dataset } => Ok(dataset),
            _ => Err(Error::Service("unexpected reply to upload".into())),
        }
    }

    pub fn query(
        &mut self,
        dataset: DatasetId,
        spec: KSpec,
        method: Option<Method>,
        tenant: u32,
        deadline_rel_us: Option<u64>,
    ) -> Result<QueryResult> {
        let req = WireRequest::Query { dataset, spec, method, tenant, deadline_rel_us };
        match self.call(&req)? {
            WireResponse::Result { result } => Ok(result),
            _ => Err(Error::Service("unexpected reply to query".into())),
        }
    }

    pub fn query_many(
        &mut self,
        dataset: DatasetId,
        specs: Vec<KSpec>,
        method: Option<Method>,
        tenant: u32,
        deadline_rel_us: Option<u64>,
    ) -> Result<Vec<QueryResult>> {
        let req = WireRequest::QueryMany { dataset, specs, method, tenant, deadline_rel_us };
        match self.call(&req)? {
            WireResponse::Results { results } => Ok(results),
            _ => Err(Error::Service("unexpected reply to query_many".into())),
        }
    }

    pub fn drop_dataset(&mut self, dataset: DatasetId) -> Result<()> {
        match self.call(&WireRequest::Drop { dataset })? {
            WireResponse::Ok => Ok(()),
            _ => Err(Error::Service("unexpected reply to drop".into())),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.call(&WireRequest::Stats)? {
            WireResponse::StatsText { text } => Ok(text),
            _ => Err(Error::Service("unexpected reply to stats".into())),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&WireRequest::Shutdown)? {
            WireResponse::Ok => Ok(()),
            _ => Err(Error::Service("unexpected reply to shutdown".into())),
        }
    }
}
