//! Reweighted least squares post-fit (the classic PROGRESS / FAST-LTS
//! efficiency step, Rousseeuw & Leroy ch. 5).
//!
//! LMS/LTS are highly robust but statistically inefficient; the standard
//! remedy is one weighted OLS refit on the observations whose standardized
//! robust residuals are small (`|r_i / σ̂| ≤ c`, σ̂ from the robust fit's
//! scale). Breakdown is inherited from the initial robust fit; efficiency
//! approaches OLS on the clean subset.

use super::estimators::{ols, residuals};
use crate::util::linalg::Mat;
use crate::{invalid_arg, Result};

#[derive(Debug, Clone)]
pub struct RlsOptions {
    /// Standardized-residual cutoff (2.5 is conventional).
    pub cutoff: f64,
}

impl Default for RlsOptions {
    fn default() -> Self {
        RlsOptions { cutoff: 2.5 }
    }
}

#[derive(Debug, Clone)]
pub struct RlsFit {
    pub theta: Vec<f64>,
    /// Observations kept (weight 1).
    pub inliers: usize,
    /// Indices flagged as outliers (weight 0).
    pub outlier_idx: Vec<usize>,
}

/// One reweighting step from a robust `(theta, scale)` estimate.
pub fn reweighted_ls(
    x: &Mat,
    y: &[f64],
    robust_theta: &[f64],
    robust_scale: f64,
    opts: &RlsOptions,
) -> Result<RlsFit> {
    let n = x.rows;
    let p = x.cols;
    if robust_scale <= 0.0 || !robust_scale.is_finite() {
        return Err(invalid_arg!("robust scale must be positive, got {robust_scale}"));
    }
    let r = residuals(x, robust_theta, y);
    let mut rows = Vec::new();
    let mut rhs = Vec::new();
    let mut outlier_idx = Vec::new();
    for i in 0..n {
        if (r[i] / robust_scale).abs() <= opts.cutoff {
            rows.push((0..p).map(|j| x.at(i, j)).collect::<Vec<f64>>());
            rhs.push(y[i]);
        } else {
            outlier_idx.push(i);
        }
    }
    if rows.len() <= p {
        return Err(invalid_arg!(
            "only {} inliers for p={p}; robust fit or scale is degenerate",
            rows.len()
        ));
    }
    let xin = Mat::from_rows(&rows)?;
    let theta = ols(&xin, &rhs)?;
    Ok(RlsFit { theta, inliers: rhs.len(), outlier_idx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::data::ContaminatedLinear;
    use crate::regression::{lms, HostSelector, LmsOptions};
    use crate::stats::Rng;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn rls_improves_lms_efficiency() {
        let mut rng = Rng::seeded(231);
        let d = ContaminatedLinear {
            n: 600,
            p: 3,
            contamination: 0.25,
            sigma: 0.5, // noisy clean data: LMS inefficiency visible
            ..Default::default()
        }
        .generate(&mut rng);
        let x = d.design();
        let mut sel = HostSelector::default();
        let fit = lms(&x, &d.y, &LmsOptions::default(), &mut sel).unwrap();
        let rls = reweighted_ls(&x, &d.y, &fit.theta, fit.scale, &RlsOptions::default()).unwrap();
        let e_lms = max_err(&fit.theta, &d.theta);
        let e_rls = max_err(&rls.theta, &d.theta);
        assert!(e_rls <= e_lms + 1e-9, "RLS should not hurt: {e_rls} vs {e_lms}");
        assert!(e_rls < 0.25, "RLS error {e_rls}");
    }

    #[test]
    fn rls_flags_true_outliers() {
        let mut rng = Rng::seeded(232);
        let d = ContaminatedLinear {
            n: 400,
            p: 3,
            contamination: 0.2,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(&mut rng);
        let x = d.design();
        let mut sel = HostSelector::default();
        let fit = lms(&x, &d.y, &LmsOptions::default(), &mut sel).unwrap();
        let rls = reweighted_ls(&x, &d.y, &fit.theta, fit.scale, &RlsOptions::default()).unwrap();
        // every contaminated row must be flagged
        let mut truth: Vec<usize> = d.outliers.clone();
        truth.sort_unstable();
        let flagged: std::collections::BTreeSet<usize> =
            rls.outlier_idx.iter().copied().collect();
        let missed = truth.iter().filter(|i| !flagged.contains(i)).count();
        assert!(missed <= truth.len() / 20, "missed {missed} of {} true outliers", truth.len());
        assert_eq!(rls.inliers + rls.outlier_idx.len(), d.n());
    }

    #[test]
    fn rejects_degenerate_scale() {
        let x = Mat::from_rows(&[vec![1.0, 1.0], vec![2.0, 1.0], vec![3.0, 1.0]]).unwrap();
        let y = [1.0, 2.0, 3.0];
        assert!(reweighted_ls(&x, &y, &[1.0, 0.0], 0.0, &RlsOptions::default()).is_err());
        assert!(reweighted_ls(&x, &y, &[1.0, 0.0], f64::NAN, &RlsOptions::default()).is_err());
        // absurdly small scale flags everything -> too few inliers
        assert!(reweighted_ls(&x, &y, &[5.0, 5.0], 1e-12, &RlsOptions::default()).is_err());
    }
}
