//! Classical (non-robust) estimators and residual helpers: OLS, LAD.

use crate::util::linalg::{qr_solve, Mat};
use crate::{algo_err, Result};

/// Residual vector r = X·θ − y.
pub fn residuals(x: &Mat, theta: &[f64], y: &[f64]) -> Vec<f64> {
    x.matvec(theta)
        .into_iter()
        .zip(y)
        .map(|(p, &yi)| p - yi)
        .collect()
}

pub fn sum_sq(r: &[f64]) -> f64 {
    r.iter().map(|v| v * v).sum()
}

pub fn sum_abs(r: &[f64]) -> f64 {
    r.iter().map(|v| v.abs()).sum()
}

/// Ordinary least squares via Householder QR.
pub fn ols(x: &Mat, y: &[f64]) -> Result<Vec<f64>> {
    qr_solve(x, y).ok_or_else(|| algo_err!("OLS: rank-deficient design"))
}

/// Least absolute deviations by iteratively-reweighted least squares.
///
/// Weighted LS with w_i = 1/max(|r_i|, eps); converges to the LAD fit for
/// well-posed designs. Breakdown point is still 0 — one bad leverage point
/// ruins it — which the robustness tests demonstrate.
pub fn lad(x: &Mat, y: &[f64], max_iters: usize) -> Result<Vec<f64>> {
    let n = x.rows;
    let p = x.cols;
    let mut theta = ols(x, y)?;
    let eps = 1e-8;
    for _ in 0..max_iters {
        let r = residuals(x, &theta, y);
        // weighted design: scale rows by sqrt(w)
        let mut rows = Vec::with_capacity(n);
        let mut wy = Vec::with_capacity(n);
        for i in 0..n {
            let w = 1.0 / r[i].abs().max(eps);
            let sw = w.sqrt();
            let row: Vec<f64> = (0..p).map(|j| x.at(i, j) * sw).collect();
            rows.push(row);
            wy.push(y[i] * sw);
        }
        let xw = Mat::from_rows(&rows)?;
        let next = qr_solve(&xw, &wy).ok_or_else(|| algo_err!("LAD: singular reweighted system"))?;
        let delta: f64 = next
            .iter()
            .zip(&theta)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        theta = next;
        if delta < 1e-10 {
            break;
        }
    }
    Ok(theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::data::ContaminatedLinear;
    use crate::stats::Rng;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn ols_recovers_clean_model() {
        let mut rng = Rng::seeded(131);
        let d = ContaminatedLinear {
            n: 500,
            p: 4,
            contamination: 0.0,
            sigma: 0.01,
            ..Default::default()
        }
        .generate(&mut rng);
        let theta = ols(&d.design(), &d.y).unwrap();
        assert!(max_err(&theta, &d.theta) < 0.01, "{theta:?} vs {:?}", d.theta);
    }

    #[test]
    fn lad_recovers_clean_model() {
        let mut rng = Rng::seeded(132);
        let d = ContaminatedLinear {
            n: 500,
            p: 3,
            contamination: 0.0,
            sigma: 0.01,
            ..Default::default()
        }
        .generate(&mut rng);
        let theta = lad(&d.design(), &d.y, 50).unwrap();
        assert!(max_err(&theta, &d.theta) < 0.02);
    }

    #[test]
    fn lad_shrugs_off_mild_vertical_outliers() {
        let mut rng = Rng::seeded(133);
        let d = ContaminatedLinear {
            n: 500,
            p: 3,
            contamination: 0.1,
            leverage_fraction: 0.0, // vertical only
            sigma: 0.05,
            ..Default::default()
        }
        .generate(&mut rng);
        let theta_lad = lad(&d.design(), &d.y, 50).unwrap();
        let theta_ols = ols(&d.design(), &d.y).unwrap();
        assert!(
            max_err(&theta_lad, &d.theta) < max_err(&theta_ols, &d.theta),
            "LAD should beat OLS on vertical outliers"
        );
    }

    #[test]
    fn ols_breaks_under_contamination() {
        let mut rng = Rng::seeded(134);
        let d = ContaminatedLinear { n: 500, p: 3, contamination: 0.3, ..Default::default() }
            .generate(&mut rng);
        let theta = ols(&d.design(), &d.y).unwrap();
        assert!(max_err(&theta, &d.theta) > 1.0, "OLS unexpectedly robust: {theta:?}");
    }

    #[test]
    fn residual_helpers() {
        let x = Mat::from_rows(&[vec![1.0, 1.0], vec![2.0, 1.0]]).unwrap();
        let r = residuals(&x, &[2.0, 0.5], &[2.0, 5.0]);
        assert_eq!(r, vec![0.5, -0.5]);
        assert!((sum_sq(&r) - 0.5).abs() < 1e-15);
        assert!((sum_abs(&r) - 1.0).abs() < 1e-15);
    }
}
