//! High-breakdown robust regression (paper §VI, application 1).
//!
//! Implements the full estimator zoo the paper discusses:
//!
//! - [`ols`] — least squares (breakdown point 0, the fragile baseline);
//! - [`lad`] — least absolute deviations via IRLS (also breakdown 0);
//! - [`lms`] — Rousseeuw's Least Median of Squares via elemental-subset
//!   search (PROGRESS-style), each candidate scored with **one median of
//!   absolute residuals** — the paper's motivating workload;
//! - [`lts`] — Least Trimmed Squares with C-steps (FAST-LTS style), whose
//!   objective is evaluated with the paper's ρ-trick (Eq. 4): the h-smallest
//!   sum of squared residuals from a *median threshold + counts*, no
//!   partial sort.
//!
//! The selection backend is pluggable ([`MedianSelector`]) so the same
//! estimators run against the host oracle or the PJRT device runtime.

pub mod data;
pub mod estimators;
pub mod lms;
pub mod lts;
pub mod rls;

pub use data::{ContaminatedLinear, RegressionData};
pub use estimators::{lad, ols, residuals, sum_abs, sum_sq};
pub use lms::{lms, LmsFit, LmsOptions};
pub use lts::{lts, trimmed_sum_via_median, LtsFit, LtsOptions};
pub use rls::{reweighted_ls, RlsFit, RlsOptions};

use crate::select::{self, HostEvaluator, Method};
use crate::Result;

/// Pluggable order-statistic backend for the estimators.
pub trait MedianSelector {
    /// k-th smallest of `v` (1-indexed).
    fn order_statistic(&mut self, v: &[f64], k: usize) -> Result<f64>;

    /// Median with the paper's `[(n+1)/2]` convention.
    fn median(&mut self, v: &[f64]) -> Result<f64> {
        self.order_statistic(v, crate::util::median_rank(v.len()))
    }
}

/// Host-backed selector using any [`Method`].
pub struct HostSelector {
    pub method: Method,
}

impl Default for HostSelector {
    fn default() -> Self {
        HostSelector { method: Method::Hybrid }
    }
}

impl MedianSelector for HostSelector {
    fn order_statistic(&mut self, v: &[f64], k: usize) -> Result<f64> {
        let mut ev = HostEvaluator::new(v);
        Ok(select::order_statistic(&mut ev, k, self.method)?.value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::sorted_order_statistic;

    #[test]
    fn host_selector_matches_oracle() {
        let v = [4.0, 1.0, 3.0, 2.0, 5.0];
        let mut s = HostSelector::default();
        assert_eq!(s.median(&v).unwrap(), 3.0);
        assert_eq!(s.order_statistic(&v, 2).unwrap(), sorted_order_statistic(&v, 2));
    }
}
