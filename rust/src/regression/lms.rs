//! Least Median of Squares (Rousseeuw 1984) — the paper's motivating
//! application: `Minimize F(θ) = Med(r_i(θ))²`.
//!
//! Numerical LMS is a global search over elemental subsets (PROGRESS): fit
//! exactly p points, score the candidate with the median of absolute
//! residuals. Every candidate costs one *median of an n-vector* — the
//! selection workload the paper accelerates. The selector is pluggable so
//! the same search runs on the host oracle or the PJRT device.

use super::estimators::residuals;
use super::MedianSelector;
use crate::stats::Rng;
use crate::util::linalg::{gauss_solve, Mat};
use crate::{invalid_arg, Result};

#[derive(Debug, Clone)]
pub struct LmsOptions {
    /// Number of elemental subsets to try. Rousseeuw's coverage bound for
    /// 30% contamination at p=4 needs ~500 for 99% confidence.
    pub subsets: usize,
    pub seed: u64,
    /// Refine the winner with a local intercept adjustment.
    pub adjust_intercept: bool,
}

impl Default for LmsOptions {
    fn default() -> Self {
        LmsOptions { subsets: 500, seed: 0xC0FFEE, adjust_intercept: true }
    }
}

#[derive(Debug, Clone)]
pub struct LmsFit {
    pub theta: Vec<f64>,
    /// Med(|r|) at the fit (the LMS criterion is its square).
    pub med_abs_residual: f64,
    /// Number of candidate evaluations (== medians computed).
    pub candidates: usize,
    /// Robust scale estimate (Rousseeuw's 1.4826 · (1 + 5/(n−p)) · med).
    pub scale: f64,
}

/// Fit LMS by elemental-subset search.
pub fn lms(
    x: &Mat,
    y: &[f64],
    opts: &LmsOptions,
    selector: &mut dyn MedianSelector,
) -> Result<LmsFit> {
    let n = x.rows;
    let p = x.cols;
    if y.len() != n {
        return Err(invalid_arg!("y length {} != rows {}", y.len(), n));
    }
    if n <= p {
        return Err(invalid_arg!("need n > p for LMS (n={n}, p={p})"));
    }
    let mut rng = Rng::seeded(opts.seed);
    let mut best_theta: Option<Vec<f64>> = None;
    let mut best_med = f64::INFINITY;
    let mut candidates = 0;

    for _ in 0..opts.subsets {
        let idx = rng.sample_indices(n, p);
        // elemental fit: solve the p×p system exactly
        let rows: Vec<Vec<f64>> = idx
            .iter()
            .map(|&i| (0..p).map(|j| x.at(i, j)).collect())
            .collect();
        let rhs: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
        let sub = Mat::from_rows(&rows)?;
        let Some(theta) = gauss_solve(&sub, &rhs) else {
            continue; // degenerate subset
        };
        let r: Vec<f64> = residuals(x, &theta, y).iter().map(|v| v.abs()).collect();
        let med = selector.median(&r)?;
        candidates += 1;
        if med < best_med {
            best_med = med;
            best_theta = Some(theta);
        }
    }

    let mut theta = best_theta
        .ok_or_else(|| crate::algo_err!("all {} elemental subsets degenerate", opts.subsets))?;

    if opts.adjust_intercept {
        // Classic LMS intercept tune-up: shift the intercept (last column)
        // to the midpoint of the shortest half of current residuals.
        let r = residuals(x, &theta, y);
        let mut sorted: Vec<f64> = r.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let h = crate::util::lts_h(n);
        let mut best_width = f64::INFINITY;
        let mut shift = 0.0;
        for i in 0..=(n - h) {
            let w = sorted[i + h - 1] - sorted[i];
            if w < best_width {
                best_width = w;
                shift = 0.5 * (sorted[i + h - 1] + sorted[i]);
            }
        }
        let pl = theta.len();
        theta[pl - 1] += shift;
        let r2: Vec<f64> = residuals(x, &theta, y).iter().map(|v| v.abs()).collect();
        let med2 = selector.median(&r2)?;
        candidates += 1;
        if med2 < best_med {
            best_med = med2;
        } else {
            theta[pl - 1] -= shift; // revert
        }
    }

    let scale = 1.4826 * (1.0 + 5.0 / (n - p) as f64) * best_med;
    Ok(LmsFit { theta, med_abs_residual: best_med, candidates, scale })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::data::ContaminatedLinear;
    use crate::regression::estimators::ols;
    use crate::regression::HostSelector;
    use crate::stats::Rng;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn survives_30_percent_contamination() {
        let mut rng = Rng::seeded(141);
        let d = ContaminatedLinear {
            n: 400,
            p: 3,
            contamination: 0.3,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lms(&d.design(), &d.y, &LmsOptions::default(), &mut sel).unwrap();
        let theta_ols = ols(&d.design(), &d.y).unwrap();
        assert!(
            max_err(&fit.theta, &d.theta) < 0.5,
            "LMS failed: {:?} vs true {:?}",
            fit.theta,
            d.theta
        );
        assert!(max_err(&theta_ols, &d.theta) > max_err(&fit.theta, &d.theta));
    }

    #[test]
    fn survives_45_percent_contamination() {
        // close to the 50% breakdown bound
        let mut rng = Rng::seeded(142);
        let d = ContaminatedLinear {
            n: 500,
            p: 2,
            contamination: 0.45,
            sigma: 0.05,
            ..Default::default()
        }
        .generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lms(
            &d.design(),
            &d.y,
            &LmsOptions { subsets: 1500, ..Default::default() },
            &mut sel,
        )
        .unwrap();
        assert!(max_err(&fit.theta, &d.theta) < 0.5, "{:?} vs {:?}", fit.theta, d.theta);
    }

    #[test]
    fn candidate_count_tracks_subsets() {
        let mut rng = Rng::seeded(143);
        let d = ContaminatedLinear { n: 100, p: 2, ..Default::default() }.generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lms(
            &d.design(),
            &d.y,
            &LmsOptions { subsets: 50, adjust_intercept: false, ..Default::default() },
            &mut sel,
        )
        .unwrap();
        assert!(fit.candidates <= 50 && fit.candidates >= 45);
        assert!(fit.med_abs_residual.is_finite());
        assert!(fit.scale > 0.0);
    }

    #[test]
    fn clean_data_near_ols_quality() {
        let mut rng = Rng::seeded(144);
        let d = ContaminatedLinear {
            n: 300,
            p: 3,
            contamination: 0.0,
            sigma: 0.05,
            ..Default::default()
        }
        .generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lms(&d.design(), &d.y, &LmsOptions::default(), &mut sel).unwrap();
        assert!(max_err(&fit.theta, &d.theta) < 0.2);
    }

    #[test]
    fn rejects_bad_shapes() {
        let x = Mat::from_rows(&[vec![1.0, 1.0], vec![2.0, 1.0]]).unwrap();
        let mut sel = HostSelector::default();
        assert!(lms(&x, &[1.0], &LmsOptions::default(), &mut sel).is_err());
        assert!(lms(&x, &[1.0, 2.0], &LmsOptions::default(), &mut sel).is_err()); // n <= p
    }
}
