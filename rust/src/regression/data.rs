//! Synthetic contaminated regression data (paper §VI setting).
//!
//! Linear model y = Xθ + ε with standard-normal design and noise, plus a
//! configurable fraction of contamination: vertical outliers (wild y) and
//! bad leverage points (wild x *and* y), the classic breakdown stressors
//! from Rousseeuw & Leroy.

use crate::stats::Rng;
use crate::util::linalg::Mat;

/// A generated regression problem with ground truth.
#[derive(Debug, Clone)]
pub struct RegressionData {
    /// Design matrix rows (n × p, last column = 1 for the intercept).
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    /// True coefficient vector (length p).
    pub theta: Vec<f64>,
    /// Indices of contaminated observations.
    pub outliers: Vec<usize>,
}

impl RegressionData {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn p(&self) -> usize {
        self.theta.len()
    }

    pub fn design(&self) -> Mat {
        Mat::from_rows(&self.x).expect("non-empty design")
    }

    /// Row-major flattened design (device upload format).
    pub fn x_flat(&self) -> Vec<f64> {
        self.x.iter().flatten().copied().collect()
    }
}

/// Generator for contaminated linear data.
#[derive(Debug, Clone)]
pub struct ContaminatedLinear {
    pub n: usize,
    /// Number of coefficients including the intercept.
    pub p: usize,
    /// Fraction of contaminated points (0.0–0.5 sensible).
    pub contamination: f64,
    /// Noise standard deviation.
    pub sigma: f64,
    /// Magnitude of vertical outliers.
    pub outlier_shift: f64,
    /// Fraction of the contamination that also gets leverage (wild x).
    pub leverage_fraction: f64,
}

impl Default for ContaminatedLinear {
    fn default() -> Self {
        ContaminatedLinear {
            n: 1000,
            p: 4,
            contamination: 0.3,
            sigma: 1.0,
            outlier_shift: 100.0,
            leverage_fraction: 0.5,
        }
    }
}

impl ContaminatedLinear {
    pub fn generate(&self, rng: &mut Rng) -> RegressionData {
        assert!(self.p >= 1 && self.n > self.p);
        // true theta in [-3, 3]
        let theta: Vec<f64> = (0..self.p).map(|_| rng.range(-3.0, 3.0)).collect();
        let mut x = Vec::with_capacity(self.n);
        let mut y = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let mut row: Vec<f64> = (0..self.p - 1).map(|_| rng.normal()).collect();
            row.push(1.0); // intercept
            let clean: f64 = row.iter().zip(&theta).map(|(a, b)| a * b).sum();
            y.push(clean + self.sigma * rng.normal());
            x.push(row);
        }
        // contaminate
        let n_bad = (self.contamination * self.n as f64).round() as usize;
        let outliers = rng.sample_indices(self.n, n_bad);
        for &i in &outliers {
            y[i] = self.outlier_shift + 5.0 * rng.normal();
            if rng.f64() < self.leverage_fraction {
                for v in x[i].iter_mut().take(self.p - 1) {
                    *v = 10.0 + rng.normal(); // bad leverage
                }
            }
        }
        RegressionData { x, y, theta, outliers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_intercept() {
        let mut rng = Rng::seeded(121);
        let d = ContaminatedLinear { n: 200, p: 3, ..Default::default() }.generate(&mut rng);
        assert_eq!(d.n(), 200);
        assert_eq!(d.p(), 3);
        assert!(d.x.iter().all(|r| r.len() == 3 && r[2] == 1.0));
        assert_eq!(d.x_flat().len(), 600);
    }

    #[test]
    fn contamination_count() {
        let mut rng = Rng::seeded(122);
        let d = ContaminatedLinear { n: 1000, contamination: 0.25, ..Default::default() }
            .generate(&mut rng);
        assert_eq!(d.outliers.len(), 250);
        // outliers really are far from the clean model
        for &i in &d.outliers {
            let clean: f64 = d.x[i].iter().zip(&d.theta).map(|(a, b)| a * b).sum();
            assert!((d.y[i] - clean).abs() > 10.0, "row {i} not contaminated");
        }
    }

    #[test]
    fn zero_contamination_is_clean() {
        let mut rng = Rng::seeded(123);
        let d = ContaminatedLinear { n: 100, contamination: 0.0, sigma: 0.0, ..Default::default() }
            .generate(&mut rng);
        assert!(d.outliers.is_empty());
        for i in 0..d.n() {
            let clean: f64 = d.x[i].iter().zip(&d.theta).map(|(a, b)| a * b).sum();
            assert!((d.y[i] - clean).abs() < 1e-12);
        }
    }
}
