//! Least Trimmed Squares with the paper's median-threshold ρ-trick (Eq. 4).
//!
//! LTS minimizes the sum of the h smallest squared residuals. The paper's
//! §VI observation: that sum needs **no partial sort** — with
//! `med = Med(|r|)` (here generalized to the h-th order statistic) and the
//! counts `b_L = #{|r_i| < t}`, `b = #{|r_i| = t}`, the trimmed sum is
//!
//! ```text
//!   Σ_{|r_i| < t} r_i²  +  a·t²,   a = h − b_L  (0 ≤ a ≤ b)
//! ```
//!
//! — one threshold reduction after one selection. [`trimmed_sum_via_median`]
//! implements exactly that; the C-step refinement (Rousseeuw & Van Driessen
//! FAST-LTS) uses it as the objective.

use super::estimators::{ols, residuals};
use super::MedianSelector;
use crate::stats::Rng;
use crate::util::linalg::Mat;
use crate::{invalid_arg, Result};

#[derive(Debug, Clone)]
pub struct LtsOptions {
    /// Random starts (elemental OLS seeds).
    pub starts: usize,
    /// C-steps per start.
    pub c_steps: usize,
    pub seed: u64,
    /// Trim count; default = the paper's h (see `util::lts_h`).
    pub h: Option<usize>,
}

impl Default for LtsOptions {
    fn default() -> Self {
        LtsOptions { starts: 20, c_steps: 12, seed: 0xBEEF, h: None }
    }
}

#[derive(Debug, Clone)]
pub struct LtsFit {
    pub theta: Vec<f64>,
    /// Sum of the h smallest squared residuals.
    pub objective: f64,
    pub h: usize,
    pub c_steps_taken: usize,
}

/// The paper's Eq. (4): trimmed sum of squares from a selection + a
/// threshold pass — no sorting.
pub fn trimmed_sum_via_median(
    abs_r: &[f64],
    h: usize,
    selector: &mut dyn MedianSelector,
) -> Result<f64> {
    let n = abs_r.len();
    if h == 0 || h > n {
        return Err(invalid_arg!("h={h} out of range for n={n}"));
    }
    let t = selector.order_statistic(abs_r, h)?;
    // threshold pass (device kernel `threshold_stats` mirrors this)
    let mut ssq_below = 0.0;
    let mut b_l = 0usize;
    for &v in abs_r {
        if v < t {
            ssq_below += v * v;
            b_l += 1;
        }
    }
    let a = h - b_l; // duplicates of the threshold to include
    Ok(ssq_below + a as f64 * t * t)
}

/// Fit LTS via multi-start C-steps.
pub fn lts(
    x: &Mat,
    y: &[f64],
    opts: &LtsOptions,
    selector: &mut dyn MedianSelector,
) -> Result<LtsFit> {
    let n = x.rows;
    let p = x.cols;
    if y.len() != n || n <= p {
        return Err(invalid_arg!("bad shapes: n={n}, p={p}, y={}", y.len()));
    }
    let h = opts.h.unwrap_or_else(|| crate::util::lts_h(n)).clamp(p + 1, n);
    let mut rng = Rng::seeded(opts.seed);
    let mut best: Option<LtsFit> = None;

    for _ in 0..opts.starts {
        // seed: OLS on a random (p+1)-subset
        let idx = rng.sample_indices(n, p + 1);
        let rows: Vec<Vec<f64>> = idx
            .iter()
            .map(|&i| (0..p).map(|j| x.at(i, j)).collect())
            .collect();
        let rhs: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
        let sub = Mat::from_rows(&rows)?;
        let Some(mut theta) = crate::util::linalg::qr_solve(&sub, &rhs) else {
            continue;
        };

        let mut prev_obj = f64::INFINITY;
        let mut steps = 0;
        for _ in 0..opts.c_steps {
            // C-step: keep the h smallest |r|, refit OLS on them.
            let abs_r: Vec<f64> = residuals(x, &theta, y).iter().map(|v| v.abs()).collect();
            let t = selector.order_statistic(&abs_r, h)?;
            let mut rows = Vec::with_capacity(h);
            let mut rhs = Vec::with_capacity(h);
            // include |r| < t fully, then pad with == t up to h
            let mut taken = 0;
            for (i, &v) in abs_r.iter().enumerate() {
                if v < t && taken < h {
                    rows.push((0..p).map(|j| x.at(i, j)).collect::<Vec<f64>>());
                    rhs.push(y[i]);
                    taken += 1;
                }
            }
            for (i, &v) in abs_r.iter().enumerate() {
                if v == t && taken < h {
                    rows.push((0..p).map(|j| x.at(i, j)).collect::<Vec<f64>>());
                    rhs.push(y[i]);
                    taken += 1;
                }
            }
            let sub = Mat::from_rows(&rows)?;
            let Some(next) = ols(&sub, &rhs).ok() else { break };
            theta = next;
            steps += 1;

            let abs_r: Vec<f64> = residuals(x, &theta, y).iter().map(|v| v.abs()).collect();
            let obj = trimmed_sum_via_median(&abs_r, h, selector)?;
            if obj >= prev_obj - 1e-12 {
                break;
            }
            prev_obj = obj;
        }

        let abs_r: Vec<f64> = residuals(x, &theta, y).iter().map(|v| v.abs()).collect();
        let objective = trimmed_sum_via_median(&abs_r, h, selector)?;
        if best.as_ref().is_none_or(|b| objective < b.objective) {
            best = Some(LtsFit { theta, objective, h, c_steps_taken: steps });
        }
    }

    best.ok_or_else(|| crate::algo_err!("all LTS starts degenerate"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::data::ContaminatedLinear;
    use crate::regression::estimators::ols;
    use crate::regression::HostSelector;
    use crate::stats::Rng;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn trimmed_sum_matches_partial_sort_definition() {
        let mut rng = Rng::seeded(151);
        let mut sel = HostSelector::default();
        for n in [5usize, 10, 101, 1000] {
            let r: Vec<f64> = (0..n).map(|_| rng.normal().abs()).collect();
            for h in [1, n / 2, crate::util::lts_h(n), n] {
                let got = trimmed_sum_via_median(&r, h, &mut sel).unwrap();
                let mut sorted = r.clone();
                sorted.sort_by(|a, b| a.total_cmp(b));
                let want: f64 = sorted[..h].iter().map(|v| v * v).sum();
                assert!((got - want).abs() <= 1e-9 * want.max(1.0), "n={n} h={h}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn trimmed_sum_with_duplicate_threshold() {
        let r = vec![1.0, 2.0, 2.0, 2.0, 3.0, 9.0];
        let mut sel = HostSelector::default();
        // h = 4: 1 + 2+2+2 squared = 1 + 12 = 13
        let got = trimmed_sum_via_median(&r, 4, &mut sel).unwrap();
        assert!((got - 13.0).abs() < 1e-12);
        // h = 2: 1 + 4
        let got = trimmed_sum_via_median(&r, 2, &mut sel).unwrap();
        assert!((got - 5.0).abs() < 1e-12);
    }

    #[test]
    fn survives_30_percent_contamination() {
        let mut rng = Rng::seeded(152);
        let d = ContaminatedLinear {
            n: 400,
            p: 3,
            contamination: 0.3,
            sigma: 0.1,
            ..Default::default()
        }
        .generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lts(&d.design(), &d.y, &LtsOptions::default(), &mut sel).unwrap();
        assert!(
            max_err(&fit.theta, &d.theta) < 0.5,
            "LTS failed: {:?} vs {:?}",
            fit.theta,
            d.theta
        );
        let theta_ols = ols(&d.design(), &d.y).unwrap();
        assert!(max_err(&theta_ols, &d.theta) > max_err(&fit.theta, &d.theta));
    }

    #[test]
    fn lts_beats_lms_statistical_efficiency() {
        // LTS is known to be more efficient than LMS on clean-ish data;
        // sanity check on moderate contamination with shared selector.
        let mut rng = Rng::seeded(153);
        let d = ContaminatedLinear {
            n: 500,
            p: 3,
            contamination: 0.2,
            sigma: 0.2,
            ..Default::default()
        }
        .generate(&mut rng);
        let mut sel = HostSelector::default();
        let lts_fit = lts(&d.design(), &d.y, &LtsOptions::default(), &mut sel).unwrap();
        let lms_fit = crate::regression::lms(
            &d.design(),
            &d.y,
            &crate::regression::LmsOptions { subsets: 300, ..Default::default() },
            &mut sel,
        )
        .unwrap();
        let e_lts = max_err(&lts_fit.theta, &d.theta);
        let e_lms = max_err(&lms_fit.theta, &d.theta);
        assert!(e_lts < 0.5 && e_lms < 0.5, "lts {e_lts} lms {e_lms}");
    }

    #[test]
    fn objective_decreases_monotonically_under_c_steps() {
        // C-step theory: each step cannot increase the trimmed objective
        let mut rng = Rng::seeded(154);
        let d = ContaminatedLinear { n: 200, p: 3, contamination: 0.2, ..Default::default() }
            .generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit1 = lts(
            &d.design(),
            &d.y,
            &LtsOptions { starts: 5, c_steps: 1, seed: 7, ..Default::default() },
            &mut sel,
        )
        .unwrap();
        let fit8 = lts(
            &d.design(),
            &d.y,
            &LtsOptions { starts: 5, c_steps: 8, seed: 7, ..Default::default() },
            &mut sel,
        )
        .unwrap();
        assert!(fit8.objective <= fit1.objective + 1e-9);
    }

    #[test]
    fn h_defaults_to_paper_convention() {
        let mut rng = Rng::seeded(155);
        let d = ContaminatedLinear { n: 101, p: 2, ..Default::default() }.generate(&mut rng);
        let mut sel = HostSelector::default();
        let fit = lts(&d.design(), &d.y, &LtsOptions::default(), &mut sel).unwrap();
        assert_eq!(fit.h, 51); // (101+1)/2
    }
}
