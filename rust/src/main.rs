//! `cp-select` — command-line front end for the coordinator.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! cp-select info                          runtime + artifact inventory
//! cp-select select   [opts]               one median/OS query
//! cp-select bench-table [opts]            regenerate Table I/II + Fig 2/3
//! cp-select trace    [opts]               Fig 4 iteration trace
//! cp-select outliers [opts]               Fig 5 sensitivity sweep
//! cp-select hybrid-sweep [opts]           §IV iteration-budget ablation
//! cp-select serve-demo [opts]             drive the selection service
//! cp-select bench-wall [opts]             wall-clock trajectory + kernel race
//! cp-select regress  [opts]               LMS/LTS robust-regression demo
//! cp-select knn      [opts]               kNN demo
//! cp-select lint     [--root DIR] [--format text|json]  in-repo invariant lint
//! cp-select cluster coordinator [opts]    TCP coordinator (serves clients + workers)
//! cp-select cluster worker --id N [opts]  TCP worker process (hosts dataset shards)
//! cp-select cluster smoke [opts]          8-client end-to-end smoke against a coordinator
//! ```
//!
//! Common options: `--config FILE`, `--backend host|device`,
//! `--artifacts DIR`, `--dtype f32|f64`, `--n N`, `--method NAME`,
//! `--dist NAME`, `--seed S`, `--out DIR`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use cp_select::cluster::{
    self, run_coordinator, run_worker, ClusterClient, RemoteBackend, ServeOptions, WorkerOptions,
};
use cp_select::config::Config;
use cp_select::coordinator::{
    lru_factory, AdaptiveWindow, CostModelPool, HostBackend, KSpec, SelectionService, ShedPolicy,
    TenantQuota,
};
use cp_select::harness::{self, report, Backend, Runner, TableConfig};
use cp_select::regression::{self, HostSelector};
use cp_select::runtime::{Flavor, Runtime};
use cp_select::select::{DType, Method};
use cp_select::stats::{Distribution, Rng};
use cp_select::testkit::Clock;
use cp_select::Result;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run_cli(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

struct Opts {
    flags: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Opts> {
        let mut flags = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                return Err(cp_select::invalid_arg!("unexpected argument {a:?}"));
            };
            let val = it
                .next()
                .ok_or_else(|| cp_select::invalid_arg!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
        }
        Ok(Opts { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| cp_select::invalid_arg!("--{key}: bad integer {v:?}")),
        }
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| cp_select::invalid_arg!("--{key}: bad integer {v:?}")),
        }
    }

    fn config(&self) -> Result<Config> {
        let mut cfg = match self.get("config") {
            Some(path) => Config::load(std::path::Path::new(path))?,
            None => Config::default(),
        };
        if let Some(dir) = self.get("artifacts") {
            cfg.artifacts_dir = PathBuf::from(dir);
        } else if cfg.artifacts_dir == PathBuf::from("artifacts") {
            cfg.artifacts_dir = Runtime::default_dir();
        }
        if let Some(d) = self.get("dtype") {
            cfg.dtype = DType::from_name(d)
                .ok_or_else(|| cp_select::invalid_arg!("--dtype: {d:?}"))?;
        }
        if let Some(m) = self.get("method") {
            cfg.default_method = Method::from_name(m)
                .ok_or_else(|| cp_select::invalid_arg!("--method: {m:?}"))?;
        }
        Ok(cfg)
    }

    fn runner(&self, cfg: &Config) -> Result<Runner> {
        match self.get("backend").unwrap_or("host") {
            "host" => Runner::new(Backend::Host),
            "device" => Runner::new(Backend::Device {
                artifacts_dir: cfg.artifacts_dir.clone(),
                flavor: cfg.kernel_flavor,
            }),
            other => Err(cp_select::invalid_arg!("--backend: {other:?} (host|device)")),
        }
    }

    fn dist(&self) -> Result<Distribution> {
        let name = self.get("dist").unwrap_or("normal");
        Distribution::from_name(name)
            .ok_or_else(|| cp_select::invalid_arg!("--dist: unknown {name:?}"))
    }

    fn out_dir(&self) -> PathBuf {
        PathBuf::from(self.get("out").unwrap_or("results"))
    }
}

// Named `run_cli` (not `run`) so the in-repo linter's name-keyed call
// graph does not conflate the CLI dispatcher with the device/client
// `run` methods and drag every subcommand into the coordinator's
// cancellation-reachable set.
fn run_cli(args: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    if cmd == "cluster" {
        let Some((mode, cluster_rest)) = rest.split_first() else {
            return Err(cp_select::invalid_arg!(
                "cluster needs a mode: coordinator|worker|smoke"
            ));
        };
        let opts = Opts::parse(cluster_rest)?;
        return match mode.as_str() {
            "coordinator" => cmd_cluster_coordinator(&opts),
            "worker" => cmd_cluster_worker(&opts),
            "smoke" => cmd_cluster_smoke(&opts),
            other => Err(cp_select::invalid_arg!(
                "unknown cluster mode {other:?} (coordinator|worker|smoke)"
            )),
        };
    }
    let opts = Opts::parse(rest)?;
    match cmd.as_str() {
        "info" => cmd_info(&opts),
        "select" => cmd_select(&opts),
        "bench-table" => cmd_bench_table(&opts),
        "bench-select" => cmd_bench_select(&opts),
        "bench-wall" => cmd_bench_wall(&opts),
        "trace" => cmd_trace(&opts),
        "outliers" => cmd_outliers(&opts),
        "hybrid-sweep" => cmd_hybrid_sweep(&opts),
        "serve-demo" => cmd_serve_demo(&opts),
        "regress" => cmd_regress(&opts),
        "knn" => cmd_knn(&opts),
        "lint" => cmd_lint(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(cp_select::invalid_arg!("unknown subcommand {other:?}")),
    }
}

fn print_usage() {
    println!(
        "cp-select — parallel median/order statistics via convex minimization\n\
         (reproduction of Beliakov 2011; see README.md)\n\n\
         subcommands: info select bench-table bench-select bench-wall trace outliers\n\
         \x20             hybrid-sweep serve-demo regress knn lint cluster\n\
         common flags: --config F --backend host|device --artifacts DIR\n\
         \x20             --dtype f32|f64 --n N --method M --dist D --seed S --out DIR\n\
         bench-wall:   --quick 1 (small sizes + 3 reps) --smoke 1 (fail if the\n\
         \x20             vectorized bin sweep is < 1.5x the scalar kernel)\n\
         \x20             --reps N --sweep-n N (kernel-race size, default 2^22)\n\
         cluster:      coordinator|worker|smoke --config F (reads [cluster]);\n\
         \x20             coordinator --listen HOST:PORT --workers N;\n\
         \x20             worker --id N --addr HOST:PORT --backend host|device;\n\
         \x20             smoke --addr HOST:PORT --n N --shutdown 0|1\n\
         serve-demo:   --latency-sla-us US (adaptive window p99 budget, default)\n\
         \x20             --batch-window-us US (pin a fixed window instead)\n\
         \x20             --batch-cap N --cost-model-sidecar FILE\n\
         \x20             --shed-policy block|shed --queue-cap N (overload shedding)\n\
         \x20             --tenant-rate R [--tenant-burst B] (per-tenant admission)\n\
         \x20             --max-resident N (LRU-evict beyond N datasets per worker)\n\
         lint:         --root DIR --format text|json (json = stable schema for CI)"
    );
}

fn cmd_info(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    println!("cp-select {}", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", cfg.artifacts_dir.display());
    match Runtime::with_flavor(&cfg.artifacts_dir, cfg.kernel_flavor) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!("artifacts: {} entries", rt.manifest.entries.len());
            let max = rt.manifest.max_bucket(
                cp_select::runtime::Kernel::FusedObjective,
                Flavor::Jnp,
                cfg.dtype,
                None,
            );
            println!("largest fused_objective bucket ({}): {:?}", cfg.dtype.name(), max);
            if let Some(n) = max {
                println!(
                    "fused_ladder widths at n={n}: {:?}",
                    rt.manifest.ladder_widths(Flavor::Jnp, cfg.dtype, n)
                );
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
    println!("methods: {}", Method::ALL.map(|m| m.name()).join(" "));
    println!("distributions: {}", Distribution::ALL.map(|d| d.name()).join(" "));
    Ok(())
}

fn cmd_select(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let n = opts.usize("n", 1 << 20)?;
    let seed = opts.u64("seed", 42)?;
    let k = opts.usize("k", cp_select::util::median_rank(n))?;
    let mut rng = Rng::seeded(seed);
    let data = opts.dist()?.sample_vec(&mut rng, n);
    let mut runner = opts.runner(&cfg)?;
    let mut ev = runner.evaluator(&data, cfg.dtype)?;
    let t0 = std::time::Instant::now();
    let r = cp_select::select::order_statistic(ev.as_mut(), k, cfg.default_method)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "n={n} k={k} method={} dtype={} value={:.12} probes={} iters={} time={ms:.3}ms",
        r.method.name(),
        cfg.dtype.name(),
        r.value,
        r.probes,
        r.iterations
    );
    for (phase, t) in r.phases.phases() {
        println!("  phase {phase}: {t:.3}ms");
    }
    Ok(())
}

fn cmd_bench_table(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let max_log2 = opts.usize("max-log2n", cfg.bench_max_log2n as usize)? as u32;
    let min_log2 = opts.usize("min-log2n", 13)? as u32;
    let table_cfg = TableConfig {
        dtype: cfg.dtype,
        log2_sizes: (min_log2..=max_log2).step_by(2).collect(),
        instances: opts.usize("instances", cfg.bench_instances)?,
        reps: opts.usize("reps", cfg.bench_reps)?,
        seed: opts.u64("seed", 0xD15EA5E)?,
        ..Default::default()
    };
    let mut runner = opts.runner(&cfg)?;
    let table = harness::run_table(&mut runner, &table_cfg)?;
    let md = report::table_markdown(&table);
    println!("{md}");
    let out = opts.out_dir();
    let stem = format!(
        "table_{}_{}",
        cfg.dtype.name(),
        if runner.is_device() { "device" } else { "host" }
    );
    report::write_result(&out, &format!("{stem}.md"), &md)?;
    report::write_result(&out, &format!("{stem}.csv"), &report::table_csv(&table))?;
    println!("wrote {out:?}/{stem}.{{md,csv}}");
    Ok(())
}

fn cmd_bench_select(opts: &Opts) -> Result<()> {
    // Emits the machine-readable BENCH_select.json perf-trajectory artifact
    // (method × n × fused reductions × wall-ms + coordinator coalescing).
    // Default output is the current directory so a repo-root invocation
    // refreshes the committed BENCH_select.json.
    let cfg = opts.config()?;
    let max_log2 = opts.usize("max-log2n", 20)? as u32;
    let min_log2 = opts.usize("min-log2n", 14)? as u32;
    let sizes: Vec<u32> = (min_log2..=max_log2).step_by(2).collect();
    let reps = opts.usize("reps", cfg.bench_reps)?;
    let mut runner = opts.runner(&cfg)?;
    let bench =
        harness::bench_select(&mut runner, &sizes, opts.u64("seed", 42)?, cfg.dtype, reps)?;
    let json = report::select_bench_json(
        &bench,
        cfg.dtype.name(),
        if runner.is_device() { "pjrt-device" } else { "host" },
    );
    print!("{json}");
    let out = PathBuf::from(opts.get("out").unwrap_or("."));
    let p = report::write_result(&out, "BENCH_select.json", &json)?;
    println!("wrote {}", p.display());
    let c = &bench.coordinator;
    println!(
        "coordinator: {} coalesced queries = {} fused reductions vs {} sequential",
        c.queries, c.concurrent_fused_reductions, c.sequential_fused_reductions
    );
    Ok(())
}

fn cmd_bench_wall(opts: &Opts) -> Result<()> {
    // The wall-clock trajectory: warmup + N reps per (method, n) row
    // summarized as median/p99, the vectorized-vs-scalar bin-sweep
    // throughput race, and a measured pass-cost fit — all committed to
    // BENCH_select.json under this host's fingerprint. `--quick 1` is the
    // CI perf-smoke shape (small sizes, 3 reps); `--smoke 1` turns the
    // ≥1.5× kernel-speedup assertion into a hard failure.
    let cfg = opts.config()?;
    let quick = opts.usize("quick", 0)? != 0;
    let smoke = opts.usize("smoke", 0)? != 0;
    let max_log2 = opts.usize("max-log2n", if quick { 16 } else { 20 })? as u32;
    let min_log2 = opts.usize("min-log2n", 14)? as u32;
    let sizes: Vec<u32> = (min_log2..=max_log2).step_by(2).collect();
    let reps = opts.usize("reps", if quick { 3 } else { cfg.bench_wall_reps })?;
    let seed = opts.u64("seed", 42)?;
    let sweep_n = opts.usize("sweep-n", 1 << 22)?;
    let mut runner = opts.runner(&cfg)?;
    let mut bench = harness::bench_select(&mut runner, &sizes, seed, cfg.dtype, reps)?;

    // Kernel throughput race at the gate size (always 2^22 by default:
    // big enough that the scalar scatter dependence, not L1 residency,
    // is what's measured).
    let sweep = harness::wall::bench_bin_sweep(sweep_n, 15, reps, seed)?;
    println!(
        "bin sweep n={} width={}: vector {:.2} GB/s vs scalar {:.2} GB/s ({:.2}x)",
        sweep.n, sweep.width, sweep.vector_gbps, sweep.scalar_gbps, sweep.speedup
    );

    // Measured pass-cost coefficients -> the PassCostModel seed path.
    let fit = harness::wall::measure_pass_cost(sweep_n, reps, seed);
    let seeded = cp_select::select::PassCostModel::seeded_from_measured(fit.sweep, fit.per_probe);
    println!(
        "pass cost: sweep {:.3e} s/elem, per-probe {:.3e} s/elem -> planned width {}",
        fit.sweep,
        fit.per_probe,
        seeded.best_width(None)
    );
    bench.bin_sweep = Some(sweep.clone());
    bench.pass_cost = Some(fit);

    let json = report::select_bench_json(
        &bench,
        cfg.dtype.name(),
        if runner.is_device() { "pjrt-device" } else { "host" },
    );
    let out = PathBuf::from(opts.get("out").unwrap_or("."));
    let p = report::write_result(&out, "BENCH_select.json", &json)?;
    println!("wrote {} (host: {})", p.display(), bench.host.cpu);
    if smoke && sweep.speedup < 1.5 {
        return Err(cp_select::Error::Service(format!(
            "perf smoke: vectorized bin sweep only {:.2}x the scalar kernel (need >= 1.5x)",
            sweep.speedup
        )));
    }
    Ok(())
}

fn cmd_trace(opts: &Opts) -> Result<()> {
    let n = opts.usize("n", 4096)?;
    let seed = opts.u64("seed", 42)?;
    let trace = harness::trace_fig4(n, seed)?;
    let csv = report::trace_csv(&trace);
    print!("{csv}");
    let p = report::write_result(&opts.out_dir(), "fig4_trace.csv", &csv)?;
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_outliers(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let n = opts.usize("n", 1 << 16)?;
    let seed = opts.u64("seed", 42)?;
    let mut runner = opts.runner(&cfg)?;
    let mags = [1e3, 1e5, 1e7, 1e9, 1e11, 1e13];
    let pts = harness::outlier_sweep_fig5(&mut runner, n, &mags, cfg.dtype, seed)?;
    let csv = report::outlier_csv(&pts);
    print!("{csv}");
    let p = report::write_result(&opts.out_dir(), "fig5_outliers.csv", &csv)?;
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_hybrid_sweep(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let n = opts.usize("n", 1 << 20)?;
    let seed = opts.u64("seed", 42)?;
    let mut runner = opts.runner(&cfg)?;
    let budgets = [0, 2, 4, 5, 7, 9, 11, 14];
    let pts = harness::hybrid_sweep(&mut runner, n, &budgets, cfg.dtype, seed)?;
    let csv = report::hybrid_sweep_csv(&pts);
    print!("{csv}");
    let p = report::write_result(&opts.out_dir(), "hybrid_sweep.csv", &csv)?;
    println!("wrote {}", p.display());
    Ok(())
}

fn cmd_serve_demo(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let n = opts.usize("n", 1 << 16)?;
    let queries = opts.usize("queries", 64)?;
    let seed = opts.u64("seed", 42)?;
    // Batching window: adaptive by default (the SLA-bounded controller —
    // `--latency-sla-us` sets its p99 budget); `--batch-window-us` pins a
    // fixed window instead (the manual override, matching the config's
    // `[service] batch_window_us` semantics).
    let mut copts = cfg.coordinator_options();
    if let Some(us) = opts.get("latency-sla-us") {
        let us: u64 = us
            .parse()
            .map_err(|_| cp_select::invalid_arg!("--latency-sla-us: bad integer {us:?}"))?;
        copts.adaptive = Some(AdaptiveWindow {
            latency_sla: std::time::Duration::from_micros(us),
            ..AdaptiveWindow::default()
        });
    }
    if let Some(us) = opts.get("batch-window-us") {
        let us: u64 = us
            .parse()
            .map_err(|_| cp_select::invalid_arg!("--batch-window-us: bad integer {us:?}"))?;
        copts.batch_window = std::time::Duration::from_micros(us);
        copts.adaptive = None;
    }
    copts.batch_cap = opts.usize("batch-cap", copts.batch_cap)?;
    // Overload hardening: shed policy, per-tenant admission, queue cap.
    if let Some(policy) = opts.get("shed-policy") {
        copts.shed_policy = ShedPolicy::parse(policy)?;
    }
    if let Some(cap) = opts.get("queue-cap") {
        let cap: usize = cap
            .parse()
            .map_err(|_| cp_select::invalid_arg!("--queue-cap: bad integer {cap:?}"))?;
        copts.queue_cap = Some(cap);
    }
    if let Some(rate) = opts.get("tenant-rate") {
        let rate: f64 = rate
            .parse()
            .map_err(|_| cp_select::invalid_arg!("--tenant-rate: bad number {rate:?}"))?;
        let burst = match opts.get("tenant-burst") {
            Some(b) => b
                .parse()
                .map_err(|_| cp_select::invalid_arg!("--tenant-burst: bad number {b:?}"))?,
            None => rate,
        };
        copts.tenant_quota = Some(TenantQuota { rate_per_sec: rate, burst });
    } else if opts.get("tenant-burst").is_some() {
        return Err(cp_select::invalid_arg!("--tenant-burst requires --tenant-rate"));
    }
    // Cost-model pool: sidecar-bound when configured (`--cost-model-sidecar`
    // or `[service] cost_model_sidecar`) so a restart plans with this run's
    // measured pass costs; in-memory otherwise.
    let pool = match opts
        .get("cost-model-sidecar")
        .map(PathBuf::from)
        .or_else(|| cfg.cost_model_sidecar.clone())
    {
        Some(path) => CostModelPool::load_or_seed(path),
        None => CostModelPool::seeded(),
    };
    // The service demo uses the host backend by default; `--backend device`
    // builds per-worker PJRT runtimes.
    let factory = match opts.get("backend").unwrap_or("host") {
        "device" => cp_select::coordinator::DeviceBackend::factory(
            cfg.artifacts_dir.clone(),
            cfg.kernel_flavor,
        ),
        _ => HostBackend::factory(),
    };
    // Residency cap (`--max-resident` / `[service] max_resident_datasets`):
    // wrap each worker's backend in LRU eviction under device-memory
    // pressure; evicted datasets answer with a "re-upload" error.
    let max_resident = match opts.get("max-resident") {
        Some(v) => {
            let v: usize = v
                .parse()
                .map_err(|_| cp_select::invalid_arg!("--max-resident: bad integer {v:?}"))?;
            Some(v)
        }
        None => cfg.max_resident_datasets,
    };
    let factory = match max_resident {
        Some(cap) => lru_factory(factory, cap),
        None => factory,
    };
    let svc = SelectionService::start_full(
        cfg.workers,
        cfg.queue_depth,
        cfg.default_method,
        factory,
        copts,
        Clock::real(),
        pool.clone(),
    )?;
    let mut rng = Rng::seeded(seed);
    let mut ids = Vec::new();
    for d in [Distribution::Normal, Distribution::HalfNormal, Distribution::Mixture1] {
        let data = d.sample_vec(&mut rng, n);
        ids.push(svc.upload(data, cfg.dtype)?);
    }
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for q in 0..queries {
        let id = ids[q % ids.len()];
        let spec = match q % 3 {
            0 => KSpec::Median,
            1 => KSpec::Quantile(0.25),
            _ => KSpec::Quantile(0.9),
        };
        rxs.push(svc.query_async(id, spec, cfg.default_method)?);
    }
    let mut ok = 0;
    for rx in rxs {
        if rx
            .recv()
            .map_err(|_| cp_select::Error::Service("reply dropped".into()))?
            .is_ok()
        {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{queries} queries over {} datasets (n={n}) in {:.3}s ({:.1} qps)",
        ids.len(),
        wall,
        queries as f64 / wall
    );
    println!("metrics: {}", svc.metrics.snapshot());
    svc.shutdown(); // persists the sidecar when the pool is bound to one
    println!(
        "cost model: {} pooled runs, planned width {}{}",
        pool.samples(),
        pool.best_width(None),
        pool.sidecar().map(|p| format!(", sidecar {}", p.display())).unwrap_or_default()
    );
    Ok(())
}

fn cmd_regress(opts: &Opts) -> Result<()> {
    let n = opts.usize("n", 2000)?;
    let p = opts.usize("p", 4)?;
    let seed = opts.u64("seed", 42)?;
    let contamination = opts
        .get("contamination")
        .map(|v| v.parse::<f64>().unwrap_or(0.3))
        .unwrap_or(0.3);
    let mut rng = Rng::seeded(seed);
    let data = regression::ContaminatedLinear { n, p, contamination, ..Default::default() }
        .generate(&mut rng);
    let x = data.design();
    let mut sel = HostSelector::default();

    let t0 = std::time::Instant::now();
    let theta_ols = regression::ols(&x, &data.y)?;
    let t_ols = t0.elapsed();
    let t0 = std::time::Instant::now();
    let fit_lms = regression::lms(&x, &data.y, &regression::LmsOptions::default(), &mut sel)?;
    let t_lms = t0.elapsed();
    let t0 = std::time::Instant::now();
    let fit_lts = regression::lts(&x, &data.y, &regression::LtsOptions::default(), &mut sel)?;
    let t_lts = t0.elapsed();

    let err = |th: &[f64]| {
        th.iter()
            .zip(&data.theta)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max)
    };
    println!("n={n} p={p} contamination={contamination}");
    println!("true theta: {:?}", data.theta);
    println!("OLS   err={:.4} time={:?}  (breaks: expected with outliers)", err(&theta_ols), t_ols);
    println!(
        "LMS   err={:.4} med|r|={:.4} candidates={} time={:?}",
        err(&fit_lms.theta),
        fit_lms.med_abs_residual,
        fit_lms.candidates,
        t_lms
    );
    println!(
        "LTS   err={:.4} objective={:.4} h={} time={:?}",
        err(&fit_lts.theta),
        fit_lts.objective,
        fit_lts.h,
        t_lts
    );
    Ok(())
}

fn cmd_knn(opts: &Opts) -> Result<()> {
    let n = opts.usize("n", 5000)?;
    let k = opts.usize("k", 15)?;
    let seed = opts.u64("seed", 42)?;
    let mut rng = Rng::seeded(seed);
    // f(x) = sin(2x0) + x1 on [0,2]²
    let mut x = Vec::with_capacity(n);
    let mut f = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.range(0.0, 2.0);
        let b = rng.range(0.0, 2.0);
        x.push(vec![a, b]);
        f.push((2.0 * a).sin() + b);
    }
    let model = cp_select::knn::KnnModel::new(x, f)?;
    let mut sel = HostSelector::default();
    let mut worst: f64 = 0.0;
    let mut sum = 0.0;
    let queries = 50;
    let t0 = std::time::Instant::now();
    for _ in 0..queries {
        let q = [rng.range(0.2, 1.8), rng.range(0.2, 1.8)];
        let pred = model.predict_regression(&q, k, &mut sel)?;
        let truth = (2.0 * q[0]).sin() + q[1];
        let e = (pred - truth).abs();
        worst = worst.max(e);
        sum += e;
    }
    println!(
        "kNN regression: n={n} k={k} queries={queries} mean|err|={:.4} max|err|={:.4} time={:?}",
        sum / queries as f64,
        worst,
        t0.elapsed()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// cluster mode

fn ms(v: u64) -> std::time::Duration {
    std::time::Duration::from_millis(v)
}

/// Serve a TCP coordinator: the plain [`SelectionService`] wired to
/// remote workers through `RemoteBackend` (one service worker thread per
/// remote worker), plus the accept loop that routes client sessions and
/// worker registrations. Blocks until a client sends shutdown.
fn cmd_cluster_coordinator(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let listen = opts.get("listen").unwrap_or(cfg.cluster.listen.as_str()).to_string();
    let workers = opts.u64("workers", cfg.cluster.workers as u64)?.max(1) as u32;
    let pool = match cfg.cost_model_sidecar.clone() {
        Some(path) => CostModelPool::load_or_seed(path),
        None => CostModelPool::seeded(),
    };
    let registry = cluster::coordinator::Registry::new();
    let factory = RemoteBackend::factory(
        registry.clone(),
        pool.clone(),
        workers,
        ms(cfg.cluster.connect_timeout_ms.max(1)),
    );
    let clock = Clock::real();
    let svc = SelectionService::start_full(
        workers as usize,
        cfg.queue_depth,
        cfg.default_method,
        factory,
        cfg.coordinator_options(),
        clock.clone(),
        pool,
    )?;
    let listener = std::net::TcpListener::bind(&listen)
        .map_err(|e| cp_select::Error::io(listen.clone(), e))?;
    println!("cluster coordinator listening on {listen} ({workers} remote workers)");
    run_coordinator(
        listener,
        svc,
        registry,
        clock,
        ServeOptions {
            client_poll: std::time::Duration::from_millis(500),
            shard_io_timeout: ms(cfg.cluster.io_timeout_ms),
        },
    )?;
    println!("cluster coordinator stopped");
    Ok(())
}

/// Run a worker process body: host (default) or device backend, serving
/// shard ops until the coordinator shuts the cluster down.
fn cmd_cluster_worker(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let id = opts
        .get("id")
        .ok_or_else(|| cp_select::invalid_arg!("cluster worker needs --id N"))?;
    let id: u32 = id
        .parse()
        .map_err(|_| cp_select::invalid_arg!("--id: bad integer {id:?}"))?;
    let addr = opts.get("addr").unwrap_or(cfg.cluster.listen.as_str()).to_string();
    let factory = match opts.get("backend").unwrap_or("host") {
        "device" => cp_select::coordinator::DeviceBackend::factory(
            cfg.artifacts_dir.clone(),
            cfg.kernel_flavor,
        ),
        _ => HostBackend::factory(),
    };
    let wopts = WorkerOptions {
        connect_timeout: ms(cfg.cluster.connect_timeout_ms),
        reconnect_backoff: std::time::Duration::from_millis(200),
        heartbeat: ms(cfg.cluster.heartbeat_ms),
    };
    println!("cluster worker {id} dialing {addr}");
    run_worker(&addr, id, factory, Clock::real(), wopts)?;
    println!("cluster worker {id} stopped");
    Ok(())
}

/// End-to-end smoke against a live coordinator: upload one dataset, fan
/// out N concurrent clients querying distinct ranks, and verify every
/// answer bit-exactly against a host-side sort. `--shutdown 1` (default)
/// stops the whole cluster afterwards, so CI can tear down by exit code.
fn cmd_cluster_smoke(opts: &Opts) -> Result<()> {
    let cfg = opts.config()?;
    let addr = opts.get("addr").unwrap_or(cfg.cluster.listen.as_str()).to_string();
    let n = opts.usize("n", 1 << 14)?;
    let seed = opts.u64("seed", 42)?;
    let clients = opts.usize("clients", 8)?.max(1);
    let shutdown = opts.usize("shutdown", 1)? != 0;
    let connect = ms(cfg.cluster.connect_timeout_ms.max(1));
    let io = ms(cfg.cluster.io_timeout_ms.max(1));

    let mut rng = Rng::seeded(seed);
    let data = Distribution::Normal.sample_vec(&mut rng, n);
    let mut sorted = data.clone();
    sorted.sort_by(f64::total_cmp);

    // The coordinator may still be binding its listener: retry briefly,
    // parking on the clock (thread::sleep is banned outside benches).
    let clock = Clock::real();
    let (_keep_alive, parker) = std::sync::mpsc::channel::<()>();
    let dial = || -> Result<ClusterClient> {
        let mut last = cp_select::Error::Service(format!("never dialed {addr}"));
        for _ in 0..50 {
            match ClusterClient::connect(&addr, connect, io) {
                Ok(c) => return Ok(c),
                Err(e) => last = e,
            }
            let _ = clock.recv_deadline(&parker, clock.now_us() + 100_000);
        }
        Err(last)
    };

    let mut main_client = dial()?;
    let dataset = main_client.upload(data, DType::F64)?;
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let rank = ((i + 1) * n / (clients + 1)).clamp(1, n);
            let addr = addr.clone();
            std::thread::spawn(move || -> Result<(usize, f64)> {
                let mut c = ClusterClient::connect(&addr, connect, io)?;
                let r = c.query(dataset, KSpec::Rank(rank), None, i as u32, None)?;
                Ok((rank, r.value))
            })
        })
        .collect();
    let mut checked = 0usize;
    for h in handles {
        let (rank, value) = h
            .join()
            .map_err(|_| cp_select::Error::Service("smoke client panicked".into()))??;
        let expected = sorted[rank - 1];
        if value.to_bits() != expected.to_bits() {
            return Err(cp_select::Error::Service(format!(
                "rank {rank}: cluster answered {value}, host sort says {expected}"
            )));
        }
        checked += 1;
    }
    println!("cluster smoke ok: {checked}/{clients} client answers bit-exact vs host sort (n={n})");
    println!("coordinator metrics: {}", main_client.stats()?);
    if shutdown {
        main_client.shutdown()?;
        println!("cluster shut down");
    }
    Ok(())
}

/// Run the in-repo invariant lint (`cp_select::analysis`) over the
/// crate's sources and tests. Exits nonzero on any finding, which is what
/// makes the CI `lint` leg blocking.
fn cmd_lint(opts: &Opts) -> Result<()> {
    let root = match opts.get("root") {
        Some(dir) => PathBuf::from(dir),
        // Works from either the repo root or `rust/` (the CI leg runs
        // `cargo run` from `rust/`).
        None if std::path::Path::new("src").is_dir() => PathBuf::from("."),
        None => PathBuf::from("rust"),
    };
    let roots: Vec<PathBuf> = ["src", "tests", "benches"]
        .iter()
        .map(|d| root.join(d))
        .filter(|p| p.is_dir())
        .collect();
    if roots.is_empty() {
        return Err(cp_select::invalid_arg!("--root {root:?}: no src/tests/benches underneath"));
    }
    let report = cp_select::analysis::lint_paths(&roots)?;
    match opts.get("format").unwrap_or("text") {
        "json" => println!("{}", report.to_json()),
        "text" => println!("{report}"),
        other => return Err(cp_select::invalid_arg!("--format {other}: expected text or json")),
    }
    if report.clean() {
        Ok(())
    } else {
        Err(cp_select::Error::Service(format!(
            "lint failed with {} finding(s)",
            report.findings.len()
        )))
    }
}
