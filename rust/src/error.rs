//! Crate-wide error type.
//!
//! Hand-implemented `Display`/`Error` (thiserror is unavailable in the
//! offline build environment).

use std::fmt;

use crate::xla;

/// Unified error for the coordinator, runtime, and applications.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, transfer).
    Xla(String),

    /// Artifact store problems: missing manifest, missing bucket, bad entry.
    Artifact(String),

    /// Manifest / config parse errors.
    Parse(String),

    /// Invalid argument from a caller (k out of range, empty input, ...).
    InvalidArg(String),

    /// An algorithm failed to converge or hit an internal inconsistency.
    Algorithm(String),

    /// Coordinator/service failures (queue closed, worker died, ...).
    Service(String),

    /// Admission control shed the request: the worker's ingest queue was
    /// full or the tenant exhausted its token bucket. `retry_after_us` is
    /// the service's estimate of when retrying is worthwhile.
    Overloaded {
        retry_after_us: u64,
    },

    /// The request's deadline passed before it was (fully) served; the
    /// coordinator abandoned it rather than spend more fused passes on a
    /// caller that has given up. `late_us` is how far past the deadline
    /// the service was when it gave up.
    DeadlineExceeded {
        late_us: u64,
    },

    /// A cluster peer (remote worker or client connection) went away
    /// mid-conversation: EOF, reset pipe, or a closed loopback channel.
    /// Fails only the in-flight batch — the same isolation contract a
    /// backend panic gets from `catch_unwind` — and the peer may
    /// re-register afterwards. `peer` names the other end for logs.
    Disconnected {
        peer: String,
    },

    /// I/O errors with path context.
    Io {
        path: String,
        source: std::io::Error,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Parse(m) => write!(f, "parse: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Algorithm(m) => write!(f, "algorithm: {m}"),
            Error::Service(m) => write!(f, "service: {m}"),
            Error::Overloaded { retry_after_us } => {
                write!(f, "overloaded: shed by admission control; retry after {retry_after_us}us")
            }
            Error::DeadlineExceeded { late_us } => {
                write!(f, "deadline exceeded: abandoned {late_us}us past the deadline")
            }
            Error::Disconnected { peer } => {
                write!(f, "disconnected: lost cluster peer {peer} mid-conversation")
            }
            Error::Io { path, source } => write!(f, "io: {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Helper to build `Error::InvalidArg` with format args.
#[macro_export]
macro_rules! invalid_arg {
    ($($t:tt)*) => { $crate::Error::InvalidArg(format!($($t)*)) };
}

/// Helper to build `Error::Algorithm` with format args.
#[macro_export]
macro_rules! algo_err {
    ($($t:tt)*) => { $crate::Error::Algorithm(format!($($t)*)) };
}

impl fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ErrorKind::Xla => "xla",
            ErrorKind::Artifact => "artifact",
            ErrorKind::Parse => "parse",
            ErrorKind::InvalidArg => "invalid-arg",
            ErrorKind::Algorithm => "algorithm",
            ErrorKind::Service => "service",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::DeadlineExceeded => "deadline-exceeded",
            ErrorKind::Disconnected => "disconnected",
            ErrorKind::Io => "io",
        };
        f.write_str(s)
    }
}

/// Coarse error classification used by service metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    Xla,
    Artifact,
    Parse,
    InvalidArg,
    Algorithm,
    Service,
    Overloaded,
    DeadlineExceeded,
    Disconnected,
    Io,
}

impl Error {
    pub fn kind(&self) -> ErrorKind {
        match self {
            Error::Xla(_) => ErrorKind::Xla,
            Error::Artifact(_) => ErrorKind::Artifact,
            Error::Parse(_) => ErrorKind::Parse,
            Error::InvalidArg(_) => ErrorKind::InvalidArg,
            Error::Algorithm(_) => ErrorKind::Algorithm,
            Error::Service(_) => ErrorKind::Service,
            Error::Overloaded { .. } => ErrorKind::Overloaded,
            Error::DeadlineExceeded { .. } => ErrorKind::DeadlineExceeded,
            Error::Disconnected { .. } => ErrorKind::Disconnected,
            Error::Io { .. } => ErrorKind::Io,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_roundtrip() {
        let e = Error::Artifact("missing".into());
        assert_eq!(e.kind(), ErrorKind::Artifact);
        assert_eq!(e.to_string(), "artifact: missing");
        assert_eq!(ErrorKind::Artifact.to_string(), "artifact");
    }

    #[test]
    fn macros_build_errors() {
        let e = invalid_arg!("k={} out of range", 7);
        assert!(matches!(e, Error::InvalidArg(_)));
        let e = algo_err!("diverged after {} iters", 3);
        assert!(matches!(e, Error::Algorithm(_)));
    }

    #[test]
    fn overload_and_deadline_variants_are_typed() {
        let e = Error::Overloaded { retry_after_us: 250 };
        assert_eq!(e.kind(), ErrorKind::Overloaded);
        assert!(e.to_string().contains("retry after 250us"));
        let e = Error::DeadlineExceeded { late_us: 40 };
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
        assert!(e.to_string().contains("40us past the deadline"));
        assert_eq!(ErrorKind::DeadlineExceeded.to_string(), "deadline-exceeded");
    }

    #[test]
    fn disconnected_variant_is_typed() {
        let e = Error::Disconnected { peer: "worker-1".into() };
        assert_eq!(e.kind(), ErrorKind::Disconnected);
        assert!(e.to_string().contains("worker-1"));
        assert_eq!(ErrorKind::Disconnected.to_string(), "disconnected");
    }

    #[test]
    fn io_error_keeps_path() {
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.to_string().contains("/tmp/x"));
    }
}
